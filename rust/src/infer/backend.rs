//! [`NativeBackend`] — the native engine packaged as an execution
//! backend for both serving surfaces:
//!
//! - [`crate::qos::QosBackend`]: the QoS evaluators hand over a pruned
//!   (tile-zeroed, optionally fake-quantized) parameter bundle; the
//!   backend recovers the tile masks from the zeroed tiles and runs with
//!   *true* skipping — the functional counterpart of what the analytic
//!   engine charges for the same masks.
//! - [`crate::coordinator::serve::ServeBackend`]: the batched serving
//!   loop executes against the native forward pass through a
//!   self-describing [`Manifest`], so `coordinator::serve` needs no PJRT
//!   artifact at all.
//!
//! For direct use (examples, benches), [`NativeBackend::prepare`] prunes
//! the backend's own master weights at a (tile, rate, quant)
//! configuration — no bundle-zeroing round trip, masks flow straight
//! from [`crate::pruning::global_prune`] into the tile-skipping kernels.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{ensure, Result};

use crate::coordinator::resilience::OperatingPoint;
use crate::coordinator::serve::ServeBackend;
use crate::data::{Bundle, DType, Tensor};
use crate::pruning::{global_prune, tile_l1_norms, PrunePlan, TileNorms};
use crate::qos::QosBackend;
use crate::runtime::{manifest::ModelMeta, ArgSpec, Manifest};
use crate::sysim::TileMask;
use crate::systolic::Quant;
use crate::telemetry;

use super::batch::BatchForward;
use super::decoder::{
    ContinuousDecoder, DecodeStats, DecoderForward, DecoderWeights, Finished, PreparedDecoder,
};
use super::encoder::{EncoderWeights, ForwardStats, ModelDims, PreparedModel};

/// Per-feed-forward-GEMM tile L1 norms of a weight set.
pub fn ff_norms(w: &EncoderWeights, tile: usize) -> Result<Vec<TileNorms>> {
    let dims = &w.dims;
    ensure!(dims.tile_ok(tile), "tile {tile} does not divide the model");
    let (d, f) = (dims.d_model, dims.d_ff);
    let mut out = Vec::with_capacity(2 * dims.n_blocks);
    for blk in &w.blocks {
        out.push(tile_l1_norms(&Tensor::from_f32(&[d, f], &blk.w1), tile));
        out.push(tile_l1_norms(&Tensor::from_f32(&[f, d], &blk.w2), tile));
    }
    Ok(out)
}

/// Recover tile masks from (possibly) tile-zeroed weights: a tile whose
/// L1 norm is exactly zero contributes nothing and is marked dead. On
/// clean weights this returns (near-)full masks; on `prepare_params`
/// output it reproduces the pruning plan's masks exactly.
pub fn recover_masks(w: &EncoderWeights, tile: usize) -> Result<Vec<TileMask>> {
    let norms = ff_norms(w, tile)?;
    Ok(norms
        .iter()
        .map(|tn| TileMask {
            kt: tn.kt,
            nt: tn.nt,
            live: tn.norms.iter().map(|v| *v != 0.0).collect(),
        })
        .collect())
}

/// The native engine as a pluggable execution backend. Batches execute
/// on the weight-stationary serving runtime ([`BatchForward`]) — every
/// live tile loaded once per batch — whose outputs are bitwise
/// identical to the per-utterance reference engine.
pub struct NativeBackend {
    master: EncoderWeights,
    /// Decoder master weights — present on the autoregressive MT path
    /// ([`NativeBackend::new_mt`]), absent for encoder-only serving.
    dec_master: Option<DecoderWeights>,
    model: PreparedModel,
    dec_model: Option<PreparedDecoder>,
    fwd: BatchForward,
    dec_fwd: DecoderForward,
    batch: usize,
    /// Stage INT8 weights with per-output-channel scales on the next
    /// `prepare`/`configure`.
    per_channel: bool,
    /// Built once (tile refreshed on re-staging) so the serving hot
    /// path neither reallocates nor reassembles it per batch.
    serve_manifest: Manifest,
    /// Worker threads [`Self::forward_batch`] spreads a batch's
    /// utterance chunks across (1 = the single-threaded path).
    threads: usize,
    /// Per-chunk batched runtimes (buffers + per-chunk stats), claimed
    /// off the work queue and reused across calls; `fwd` stays the
    /// canonical stats accumulator.
    shard_fwds: Vec<BatchForward>,
    /// Per-chunk output buffers, concatenated in utterance order.
    shard_outs: Vec<Vec<f32>>,
    /// Deterministic fault hook for the containment tests: a worker
    /// panics when any of its utterances' first feature element equals
    /// this marker. `None` (the default) never fires.
    panic_marker: Option<f32>,
}

/// Deterministic panicking stub: blow up the calling worker when any
/// utterance in `feats` starts with the armed marker value.
fn panic_if_marked(feats: &[f32], marker: Option<f32>, t: usize, f: usize) {
    if let Some(m) = marker {
        let stride = t * f;
        if stride == 0 {
            return;
        }
        for u in 0..feats.len() / stride {
            assert!(
                feats[u * stride] != m,
                "injected worker panic (marker {m})"
            );
        }
    }
}

impl NativeBackend {
    /// Stage `weights` dense at their default tile, FP32. `batch` is the
    /// serving batch size (the QoS path accepts any batch).
    pub fn new(weights: EncoderWeights, batch: usize) -> Result<Self> {
        ensure!(batch > 0, "batch must be positive");
        let model = PreparedModel::new(&weights, weights.dims.tile, Quant::Fp32, None)?;
        let serve_manifest = build_manifest(&weights.dims, batch, model.tile);
        Ok(NativeBackend {
            master: weights,
            dec_master: None,
            model,
            dec_model: None,
            fwd: BatchForward::new(),
            dec_fwd: DecoderForward::new(),
            batch,
            per_channel: false,
            serve_manifest,
            threads: 1,
            shard_fwds: Vec::new(),
            shard_outs: Vec::new(),
            panic_marker: None,
        })
    }

    /// Arm the deterministic worker-panic hook: any utterance whose
    /// first feature element equals `marker` panics its worker thread —
    /// how the fault-containment tests blow up exactly one shard.
    pub fn set_panic_marker(&mut self, marker: Option<f32>) {
        self.panic_marker = marker;
    }

    /// Stage a full MT model: token-input encoder + autoregressive
    /// decoder, both dense FP32 at their default tiles. The decoder
    /// participates in every subsequent `prepare`/`configure`
    /// (joint pruning, shared quant format) and powers
    /// [`Self::translate`].
    pub fn new_mt(enc: EncoderWeights, dec: DecoderWeights, batch: usize) -> Result<Self> {
        ensure!(enc.dims.token_input, "MT backend needs a token-input encoder");
        ensure!(
            enc.dims.d_model == dec.dims.d_model
                && enc.dims.n_heads == dec.dims.n_heads
                && enc.dims.vocab == dec.dims.vocab
                && enc.dims.tile == dec.dims.tile,
            "encoder/decoder dims mismatch"
        );
        let dec_model = PreparedDecoder::new(&dec, dec.dims.tile, Quant::Fp32, None)?;
        let mut be = Self::new(enc, batch)?;
        be.dec_master = Some(dec);
        be.dec_model = Some(dec_model);
        Ok(be)
    }

    pub fn dims(&self) -> &ModelDims {
        &self.master.dims
    }

    /// The master (unpruned FP32) weights this backend was built over.
    pub fn weights(&self) -> &EncoderWeights {
        &self.master
    }

    /// The serving batch size the manifest publishes.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// The currently staged model configuration.
    pub fn model(&self) -> &PreparedModel {
        &self.model
    }

    /// Use per-output-channel INT8 scales ([`crate::quant`]) on the
    /// next `prepare`/`configure` (tighter PTQ at high rates).
    pub fn set_per_channel(&mut self, on: bool) {
        self.per_channel = on;
    }

    /// Whether the next staging uses per-channel INT8 scales.
    pub fn per_channel(&self) -> bool {
        self.per_channel
    }

    /// Cumulative schedule statistics since the last reset.
    pub fn stats(&self) -> &ForwardStats {
        &self.fwd.stats
    }

    /// Cumulative decode-scope statistics (the autoregressive MT path).
    pub fn decode_stats(&self) -> &DecodeStats {
        &self.dec_fwd.stats
    }

    pub fn reset_stats(&mut self) {
        self.fwd.stats = ForwardStats::default();
        self.dec_fwd.stats = DecodeStats::default();
    }

    /// The staged decoder configuration, when this is an MT backend.
    pub fn dec_model(&self) -> Option<&PreparedDecoder> {
        self.dec_model.as_ref()
    }

    /// Prune the master weights at `(tile, rate)` via the global L1
    /// ranking and stage the model in `quant` format. On the MT path the
    /// decoder's feed-forward GEMMs join the **same global ranking**, so
    /// one rate governs encode- and decode-side sparsity. Returns the
    /// plan (masks + achieved rate); the staged kernels skip those
    /// tiles.
    pub fn prepare(&mut self, tile: usize, rate: f64, quant: Quant) -> Result<PrunePlan> {
        let mut norms = ff_norms(&self.master, tile)?;
        let enc_gemms = norms.len();
        if let Some(dec) = &self.dec_master {
            norms.extend(dec.ff_norms(tile)?);
        }
        let plan = global_prune(&norms, rate);
        self.model = PreparedModel::new_with(
            &self.master,
            tile,
            quant,
            Some(&plan.masks[..enc_gemms]),
            self.per_channel,
        )?;
        if let Some(dec) = &self.dec_master {
            self.dec_model = Some(PreparedDecoder::new_with(
                dec,
                tile,
                quant,
                Some(&plan.masks[enc_gemms..]),
                self.per_channel,
            )?);
        }
        self.serve_manifest.model.tile = tile;
        Ok(plan)
    }

    /// Worker threads batched execution shards a batch's utterances
    /// across (clamped to at least 1). The default is single-threaded;
    /// the serving loop sets this from the
    /// [`crate::coordinator::serve::ServeConfig`] `threads` knob.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Contiguous near-equal work-queue chunk lengths for `batch`
    /// utterances (the first `batch % chunks` chunks take the extra
    /// utterance). With one worker the whole batch stays a single chunk
    /// — the canonical single-runtime path, whose batch-level
    /// [`crate::systolic::TileTiming::batched`] accounting the
    /// functional==analytic cross-checks pin down. With `threads`
    /// workers the batch splits into `min(batch, 2 * threads)` chunks
    /// that workers claim off an atomic cursor (like
    /// `Explorer::sweep`) — more chunks than workers, so a worker stuck
    /// on an expensive chunk (long pad tails) is stolen around instead
    /// of waited on. Deterministic, so the merged chunk accounting is
    /// too (it depends only on the chunk lengths, never on which worker
    /// ran a chunk).
    pub fn chunk_sizes(batch: usize, threads: usize) -> Vec<usize> {
        if threads <= 1 || batch <= 1 {
            return vec![batch];
        }
        let chunks = batch.min(2 * threads);
        let base = batch / chunks;
        let extra = batch % chunks;
        (0..chunks).map(|i| base + usize::from(i < extra)).collect()
    }

    /// Run one padded batch of utterances through the weight-stationary
    /// engine; returns CTC log-probs `[batch, seq, vocab]` flattened.
    pub fn forward_batch(&mut self, feats: &[f32], pad: &[f32], batch: usize) -> Vec<f32> {
        let mut lp = Vec::new();
        self.forward_batch_into(feats, pad, batch, &mut lp);
        lp
    }

    /// [`Self::forward_batch`] into a caller-owned buffer. With more
    /// than one worker thread configured, the batch's utterances split
    /// into contiguous chunks ([`Self::chunk_sizes`]) that a
    /// `std::thread::scope` pool claims off an atomic work cursor
    /// (mirroring `Explorer::sweep`) — one [`BatchForward`] runtime per
    /// chunk, reused across calls, so a worker that finishes early
    /// steals the next chunk instead of idling behind a ragged one.
    /// Each utterance's log-probs are **bitwise identical** to the
    /// single-threaded run — the batched forward is bitwise
    /// per-utterance-exact for any batch split — and the merged
    /// statistics charge exactly what each chunk executed
    /// ([`crate::systolic::TileTiming::batched`] at the chunk's batch),
    /// keeping the functional==analytic cross-checks valid under work
    /// stealing: the charges depend only on the deterministic chunk
    /// lengths, never on which worker claimed a chunk.
    pub fn forward_batch_into(
        &mut self,
        feats: &[f32],
        pad: &[f32],
        batch: usize,
        out: &mut Vec<f32>,
    ) {
        let failed = self.forward_batch_contained(feats, pad, batch, out);
        assert!(
            failed.is_empty(),
            "forward_batch worker panicked for utterances {failed:?}"
        );
    }

    /// [`Self::forward_batch_into`] with per-chunk fault containment: a
    /// panic inside one chunk (or the single-threaded runtime) fails
    /// only that chunk's utterances — their output rows are zero-filled
    /// for alignment and their indices returned — instead of unwinding
    /// through the serving loop and killing the server. The unwind is
    /// caught inside the stealing worker's claim loop, so a poisoned
    /// chunk does not take the worker (or any chunk it would have
    /// claimed next) down with it. A panicked chunk's runtime is
    /// replaced fresh (its buffers may be mid-mutation) and its
    /// statistics are not merged: a failed chunk charges nothing.
    pub fn forward_batch_contained(
        &mut self,
        feats: &[f32],
        pad: &[f32],
        batch: usize,
        out: &mut Vec<f32>,
    ) -> Vec<usize> {
        let dims = &self.model.dims;
        let (t, f, v) = (dims.seq_len, dims.input_dim, dims.vocab);
        assert_eq!(feats.len(), batch * t * f, "feats must be batch x seq x input");
        assert_eq!(pad.len(), batch * t, "pad mask must be batch x seq");
        let marker = self.panic_marker;
        let chunks = Self::chunk_sizes(batch, self.threads);
        if chunks.len() <= 1 {
            // Single runtime: catch the unwind and restore the
            // cumulative counters into a fresh runtime.
            let mut span = telemetry::Span::begin("shard.forward");
            if span.is_live() {
                span.attr("shard", 0usize);
                span.attr("rows", batch);
            }
            let saved = self.fwd.stats;
            let model = &self.model;
            let fwd = &mut self.fwd;
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                panic_if_marked(feats, marker, t, f);
                fwd.run_feats(model, batch, feats, pad, out);
            }));
            return match run {
                Ok(()) => {
                    if span.is_live() {
                        // The runtime accumulates across calls; charge
                        // the span with this call's delta only.
                        self.fwd.stats.total().minus(&saved.total()).annotate(&mut span);
                    }
                    Vec::new()
                }
                Err(_) => {
                    span.attr("panicked", 1u64);
                    self.fwd = BatchForward::new();
                    self.fwd.stats = saved;
                    out.clear();
                    out.resize(batch * t * v, 0.0);
                    (0..batch).collect()
                }
            };
        }
        let n = chunks.len();
        if self.shard_fwds.len() < n {
            self.shard_fwds.resize_with(n, BatchForward::new);
        }
        if self.shard_outs.len() < n {
            self.shard_outs.resize_with(n, Vec::new);
        }
        let model = &self.model;
        // Chunk start offsets (in utterances), fixed up front — workers
        // only decide *who* runs a chunk, never *what* it contains.
        let mut starts = Vec::with_capacity(n);
        let mut u0 = 0usize;
        for &len in &chunks {
            starts.push(u0);
            u0 += len;
        }
        let panicked: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
        let cursor = AtomicUsize::new(0);
        // Per-chunk work slots: each holds the chunk's runtime (counters
        // zeroed so the post-join merge adds exactly this call's work)
        // and output buffer. The atomic cursor hands each index to
        // exactly one worker; the mutex encodes that exclusivity.
        let slots: Vec<Mutex<(&mut BatchForward, &mut Vec<f32>)>> = self.shard_fwds[..n]
            .iter_mut()
            .zip(self.shard_outs[..n].iter_mut())
            .map(|(fwd, sout)| {
                fwd.stats = ForwardStats::default();
                Mutex::new((fwd, sout))
            })
            .collect();
        let workers = self.threads.min(n);
        let parent = telemetry::current_span();
        std::thread::scope(|s| {
            for wi in 0..workers {
                let (slots, chunks, starts) = (&slots, &chunks, &starts);
                let (panicked, cursor) = (&panicked, &cursor);
                s.spawn(move || {
                    // Worker-thread root span, parented to the flush
                    // span on the serving thread.
                    let mut span = telemetry::Span::begin_with_parent("shard.forward", parent);
                    let mut rows = 0usize;
                    let mut claimed = 0usize;
                    let mut done = ForwardStats::default();
                    loop {
                        // ordering: Relaxed — work-stealing cursor; the
                        // claim only needs atomicity, chunk data flows
                        // through the per-slot mutexes.
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= chunks.len() {
                            break;
                        }
                        let (len, c0) = (chunks[i], starts[i]);
                        let sf = &feats[c0 * t * f..(c0 + len) * t * f];
                        let sp = &pad[c0 * t..(c0 + len) * t];
                        let mut slot = slots[i].lock().unwrap();
                        let (fwd, sout) = &mut *slot;
                        // Catch the unwind *inside* the claim loop: a
                        // poisoned chunk must not kill this worker or
                        // strand the chunks it would have claimed next.
                        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            panic_if_marked(sf, marker, t, f);
                            fwd.run_feats(model, len, sf, sp, sout);
                        }));
                        match run {
                            Ok(()) => {
                                rows += len;
                                claimed += 1;
                                done.add(&fwd.stats);
                            }
                            // ordering: Relaxed — the flag is read only
                            // after scope join, which synchronizes.
                            Err(_) => panicked[i].store(true, Ordering::Relaxed),
                        }
                    }
                    if span.is_live() {
                        span.attr("worker", wi);
                        span.attr("chunks", claimed);
                        span.attr("rows", rows);
                        done.total().annotate(&mut span);
                    }
                });
            }
        });
        drop(slots);
        out.clear();
        out.reserve(batch * t * v);
        // Concatenate in utterance order and merge each chunk's counters
        // into the canonical accumulator — chunk order, not claim order,
        // so the merged accounting is deterministic (only the chunks
        // this call used — the pools may be larger from an earlier
        // call).
        let mut failed = Vec::new();
        let mut u0 = 0usize;
        for (i, &len) in chunks.iter().enumerate() {
            // ordering: Relaxed — set before the scope join above; the
            // join is the synchronization point.
            if panicked[i].load(Ordering::Relaxed) {
                out.resize(out.len() + len * t * v, 0.0);
                failed.extend(u0..u0 + len);
                self.shard_fwds[i] = BatchForward::new();
            } else {
                out.extend_from_slice(&self.shard_outs[i]);
                self.fwd.stats.add(&self.shard_fwds[i].stats);
            }
            u0 += len;
        }
        failed
    }

    /// The serving manifest this backend satisfies — same contract shape
    /// the AOT artifacts publish, with only the data arguments.
    pub fn manifest(&self) -> &Manifest {
        &self.serve_manifest
    }

    /// Autoregressive MT over one ragged batch: encode all sources with
    /// real pad masks, precompute every decoder block's cross-attention
    /// K/V **weight-stationary across the batch** (each live tile
    /// loaded/dequantized once, [`crate::systolic::TileTiming::batched`]
    /// accounting over the full padded `[batch * seq_len]` panel — the
    /// rectangular batched schedule, same as the batched encoder; the
    /// valid `src_len` rows are sliced per utterance), then greedy-decode
    /// each utterance on the KV-cache runtime. Per-utterance outputs are
    /// bitwise identical to the batch-of-one path (tested below).
    pub fn translate(&mut self, src: &[i32], src_len: &[usize]) -> Result<Vec<Vec<i32>>> {
        let (ck, cv) = self.encode_cross_kv(src, src_len)?;
        let dims = self.model.dims;
        let (t, d) = (dims.seq_len, dims.d_model);
        let dec = self.dec_model.as_ref().expect("checked by encode_cross_kv");
        let batch = src_len.len();

        // Per-utterance greedy decode over the shared precompute.
        let mut out = Vec::with_capacity(batch);
        let mut hyp = Vec::new();
        for (u, &len) in src_len.iter().enumerate() {
            let base = u * t * d;
            self.dec_fwd.start_with(dec, len, |i| {
                (
                    &ck[i][base..base + len * d],
                    &cv[i][base..base + len * d],
                )
            });
            self.dec_fwd.generate_started(dec, &mut hyp);
            out.push(hyp.clone());
        }
        Ok(out)
    }

    /// Batched encode (real pad masks) plus the batched
    /// weight-stationary cross-attention K/V precompute: one `[batch *
    /// seq_len, d]` panel per decoder block, each live tile
    /// loaded/dequantized once for the whole batch
    /// ([`crate::systolic::TileTiming::batched`]). Returns the per-block
    /// K and V panels; the valid `src_len` rows are sliced per
    /// utterance by the decode paths. Charges the precompute to the
    /// decode-scope `cross_kv` accounting.
    fn encode_cross_kv(
        &mut self,
        src: &[i32],
        src_len: &[usize],
    ) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>)> {
        let dims = self.model.dims;
        ensure!(dims.token_input, "MT translation on a feature-input model");
        let dec = self
            .dec_model
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("backend has no decoder staged"))?;
        let batch = src_len.len();
        ensure!(batch > 0, "empty batch");
        let t = dims.seq_len;
        ensure!(src.len() == batch * t, "src must be batch x seq");
        for (u, &len) in src_len.iter().enumerate() {
            ensure!(
                len > 0 && len <= t,
                "utterance {u}: src_len {len} out of 1..={t}"
            );
        }

        // Batched encode (real pad masks) → post-ln_f memory panel.
        let mut memory = Vec::new();
        self.fwd
            .memory_tokens(&self.model, batch, src, src_len, &mut memory);

        let n_blocks = dec.blocks.len();
        let mut ck: Vec<Vec<f32>> = vec![Vec::new(); n_blocks];
        let mut cv: Vec<Vec<f32>> = vec![Vec::new(); n_blocks];
        let mut wtile = Vec::new();
        for (i, blk) in dec.blocks.iter().enumerate() {
            let sk = blk
                .xk
                .gemm_batched(&memory, batch, t, None, dec.tile, &mut ck[i], &mut wtile);
            let sv = blk
                .xv
                .gemm_batched(&memory, batch, t, None, dec.tile, &mut cv[i], &mut wtile);
            self.dec_fwd.stats.cross_kv.add(&sk);
            self.dec_fwd.stats.cross_kv.add(&sv);
            crate::infer::layers::record(
                crate::infer::Layer::CrossKv, &sk, dec.tile, dec.quant,
            );
            crate::infer::layers::record(
                crate::infer::Layer::CrossKv, &sv, dec.tile, dec.quant,
            );
        }
        Ok((ck, cv))
    }

    /// [`Self::translate`] on the continuous (iteration-level)
    /// scheduler: same batched encode + cross-K/V precompute, then all
    /// utterances decode through a `max_slots`-wide
    /// [`ContinuousDecoder`] with a FIFO refill queue — an EOS'd or
    /// max-len'd slot retires and the next queued utterance joins
    /// before the following step, so every step's `[k, d]` GEMV panels
    /// stay as full as the queue allows. Outputs are **bitwise
    /// identical** to [`Self::translate`] per utterance (the panel-step
    /// contract, property-tested in both modules); alongside them the
    /// per-step slot-count schedule is returned — the panel-fill
    /// evidence, and the exact input
    /// [`crate::sysim::engine::gemm_on_array_decode_batched`] needs to
    /// reproduce the run's decode charges analytically.
    pub fn translate_continuous(
        &mut self,
        src: &[i32],
        src_len: &[usize],
        max_slots: usize,
    ) -> Result<(Vec<Vec<i32>>, Vec<usize>)> {
        ensure!(max_slots > 0, "need at least one decode slot");
        let (ck, cv) = self.encode_cross_kv(src, src_len)?;
        let dims = self.model.dims;
        let (t, d) = (dims.seq_len, dims.d_model);
        let dec = self.dec_model.as_ref().expect("checked by encode_cross_kv");
        let batch = src_len.len();

        let mut cd = ContinuousDecoder::new(max_slots.min(batch));
        let mut outs: Vec<Vec<i32>> = vec![Vec::new(); batch];
        let mut next = 0usize;
        loop {
            while cd.live() < cd.max_slots() && next < batch {
                let (u, len) = (next, src_len[next]);
                let base = u * t * d;
                cd.admit(dec, u as u64, len, |i| {
                    (
                        &ck[i][base..base + len * d],
                        &cv[i][base..base + len * d],
                    )
                });
                next += 1;
            }
            if cd.live() == 0 {
                break;
            }
            for fin in cd.step(dec) {
                outs[fin.id as usize] = fin.tokens;
            }
        }
        let schedule = cd.step_batches().to_vec();
        self.dec_fwd.stats.add(&cd.stats);
        Ok((outs, schedule))
    }

    /// Join utterances into a live continuous-decode session: batched
    /// encode + cross-K/V for the joiners (one weight-stationary panel
    /// per block across all of them — the amortization survives even
    /// mid-flight joins), then admit each under its caller-chosen id.
    /// The serving loop calls this between steps as slots free up.
    pub fn decode_join(
        &mut self,
        cd: &mut ContinuousDecoder,
        ids: &[u64],
        src: &[i32],
        src_len: &[usize],
    ) -> Result<()> {
        ensure!(ids.len() == src_len.len(), "one id per joining utterance");
        ensure!(
            cd.live() + ids.len() <= cd.max_slots(),
            "{} joiners into {} free slots",
            ids.len(),
            cd.max_slots() - cd.live()
        );
        let (ck, cv) = self.encode_cross_kv(src, src_len)?;
        let dims = self.model.dims;
        let (t, d) = (dims.seq_len, dims.d_model);
        let dec = self.dec_model.as_ref().expect("checked by encode_cross_kv");
        for (u, (&id, &len)) in ids.iter().zip(src_len).enumerate() {
            let base = u * t * d;
            cd.admit(dec, id, len, |i| {
                (
                    &ck[i][base..base + len * d],
                    &cv[i][base..base + len * d],
                )
            });
        }
        Ok(())
    }

    /// One lockstep panel step of a continuous-decode session; retired
    /// slots come back so the serving loop can respond and refill.
    pub fn decode_step(&self, cd: &mut ContinuousDecoder) -> Result<Vec<Finished>> {
        let dec = self
            .dec_model
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("backend has no decoder staged"))?;
        Ok(cd.step(dec))
    }
}

/// Build the native serving manifest for one configuration.
fn build_manifest(dims: &ModelDims, batch: usize, tile: usize) -> Manifest {
    let (b, t) = (batch, dims.seq_len);
    let (name, args) = if dims.token_input {
        (
            "native_mt_encoder".to_string(),
            vec![ArgSpec {
                name: "src".to_string(),
                shape: vec![b, t],
                dtype: DType::I32,
            }],
        )
    } else {
        (
            "native_asr_encoder".to_string(),
            vec![
                ArgSpec {
                    name: "feats".to_string(),
                    shape: vec![b, t, dims.input_dim],
                    dtype: DType::F32,
                },
                ArgSpec {
                    name: "pad_mask".to_string(),
                    shape: vec![b, t],
                    dtype: DType::F32,
                },
            ],
        )
    };
    Manifest {
        name,
        args,
        output_shape: vec![b, t, dims.vocab],
        output_dtype: DType::F32,
        model: ModelMeta {
            d_model: dims.d_model,
            d_ff: dims.d_ff,
            n_blocks: dims.n_blocks,
            vocab: dims.vocab,
            tile,
            ctc_blank: dims.ctc_blank as i64,
            batch: b,
            seq_len: t,
            token_input: dims.token_input,
        },
    }
}

impl QosBackend for NativeBackend {
    fn configure(&mut self, params: &Bundle, tile: usize, quant: Quant) -> Result<()> {
        let w = EncoderWeights::from_bundle(self.master.dims, params)?;
        // Recover skipping at the evaluation tile when it is legal for
        // these dimensions; otherwise at the model's own default tile
        // (the recovered masks are conservative either way: only
        // exactly-zero tiles are skipped).
        let tile = if w.dims.tile_ok(tile) { tile } else { w.dims.tile };
        let masks = recover_masks(&w, tile)?;
        // The artifact contract's per-channel flag: a bundle staged with
        // per-channel scales carries the `quant.per_channel` marker, so
        // both backends (native here, PJRT python-side) stage the same
        // quantization scheme without out-of-band configuration.
        let pc = self.per_channel || params.get("quant.per_channel").is_some();
        self.model = PreparedModel::new_with(&w, tile, quant, Some(&masks), pc)?;
        if let Some(dec_master) = &self.dec_master {
            let dw = DecoderWeights::from_bundle(dec_master.dims, params)?;
            let dec_masks = dw.recover_masks(tile)?;
            self.dec_model = Some(PreparedDecoder::new_with(
                &dw,
                tile,
                quant,
                Some(&dec_masks),
                pc,
            )?);
        }
        self.serve_manifest.model.tile = tile;
        Ok(())
    }

    fn run_asr(&mut self, feats: &[f32], pad: &[f32], batch: usize) -> Result<Vec<f32>> {
        let dims = self.model.dims;
        ensure!(!dims.token_input, "ASR inference on a token-input model");
        let (t, f) = (dims.seq_len, dims.input_dim);
        ensure!(
            feats.len() == batch * t * f && pad.len() == batch * t,
            "ASR batch shapes: feats {} (want {}), pad {} (want {})",
            feats.len(),
            batch * t * f,
            pad.len(),
            batch * t
        );
        Ok(self.forward_batch(feats, pad, batch))
    }

    fn run_mt(&mut self, src: &[i32], batch: usize) -> Result<Vec<f32>> {
        let dims = self.model.dims;
        ensure!(dims.token_input, "MT inference on a feature-input model");
        ensure!(src.len() == batch * dims.seq_len, "src must be batch x seq");
        let mut logits = Vec::new();
        self.fwd.run_tokens(&self.model, batch, src, &mut logits);
        Ok(logits)
    }

    fn translate(&mut self, src: &[i32], src_len: &[usize], batch: usize) -> Result<Vec<Vec<i32>>> {
        ensure!(src_len.len() == batch, "one src_len per utterance");
        NativeBackend::translate(self, src, src_len)
    }
}

impl ServeBackend for NativeBackend {
    fn execute(&mut self, _artifact: &str, args: &[Tensor]) -> Result<Tensor> {
        // The manifest is cached; its arg order is fixed at construction
        // (feats + pad_mask, or src for token-input models). Validation
        // is shape/dtype checks only.
        self.serve_manifest.validate_args(args)?;
        let out = if self.model.dims.token_input {
            let src = args[0].i32s();
            let mut logits = Vec::new();
            self.fwd.run_tokens(&self.model, self.batch, &src, &mut logits);
            logits
        } else {
            let feats = args[0].f32s();
            let pad = args[1].f32s();
            self.forward_batch(&feats, &pad, self.batch)
        };
        Ok(Tensor::from_f32(&self.serve_manifest.output_shape, &out))
    }

    fn any_batch(&self) -> bool {
        true
    }

    fn set_threads(&mut self, threads: usize) {
        NativeBackend::set_threads(self, threads);
    }

    fn execute_rows(&mut self, _artifact: &str, args: &[Tensor], rows: usize) -> Result<Tensor> {
        // The dynamic-batch contract: the arguments carry exactly
        // `rows` utterances, validated here against the model dims (the
        // cached manifest's shapes describe the fixed-batch contract).
        let dims = self.model.dims;
        ensure!(rows > 0, "dynamic batch must be non-empty");
        let t = dims.seq_len;
        let logits = if dims.token_input {
            ensure!(args.len() == 1, "token serving takes one 'src' argument");
            ensure!(
                args[0].shape == [rows, t] && args[0].dtype == DType::I32,
                "src shape {:?}/{:?} != [{rows}, {t}] i32",
                args[0].shape,
                args[0].dtype
            );
            let src = args[0].i32s();
            let mut logits = Vec::new();
            self.fwd.run_tokens(&self.model, rows, &src, &mut logits);
            logits
        } else {
            ensure!(args.len() == 2, "ASR serving takes feats + pad_mask");
            ensure!(
                args[0].shape == [rows, t, dims.input_dim] && args[0].dtype == DType::F32,
                "feats shape {:?}/{:?} != [{rows}, {t}, {}] f32",
                args[0].shape,
                args[0].dtype,
                dims.input_dim
            );
            ensure!(
                args[1].shape == [rows, t] && args[1].dtype == DType::F32,
                "pad_mask shape {:?}/{:?} != [{rows}, {t}] f32",
                args[1].shape,
                args[1].dtype
            );
            let feats = args[0].f32s();
            let pad = args[1].f32s();
            let mut lp = Vec::new();
            self.forward_batch_into(&feats, &pad, rows, &mut lp);
            lp
        };
        Ok(Tensor::from_f32(&[rows, t, dims.vocab], &logits))
    }

    fn execute_rows_partial(
        &mut self,
        artifact: &str,
        args: &[Tensor],
        rows: usize,
    ) -> Result<(Tensor, Vec<usize>)> {
        let dims = self.model.dims;
        if dims.token_input {
            // The token path runs on the single canonical runtime; no
            // shard-level containment to report.
            return Ok((self.execute_rows(artifact, args, rows)?, Vec::new()));
        }
        ensure!(rows > 0, "dynamic batch must be non-empty");
        let t = dims.seq_len;
        ensure!(args.len() == 2, "ASR serving takes feats + pad_mask");
        ensure!(
            args[0].shape == [rows, t, dims.input_dim] && args[0].dtype == DType::F32,
            "feats shape {:?}/{:?} != [{rows}, {t}, {}] f32",
            args[0].shape,
            args[0].dtype,
            dims.input_dim
        );
        ensure!(
            args[1].shape == [rows, t] && args[1].dtype == DType::F32,
            "pad_mask shape {:?}/{:?} != [{rows}, {t}] f32",
            args[1].shape,
            args[1].dtype
        );
        let feats = args[0].f32s();
        let pad = args[1].f32s();
        let mut lp = Vec::new();
        let failed = self.forward_batch_contained(&feats, &pad, rows, &mut lp);
        Ok((Tensor::from_f32(&[rows, t, dims.vocab], &lp), failed))
    }

    fn set_operating_point(&mut self, point: &OperatingPoint) -> Result<bool> {
        // Re-stage from the master weights: `prepare` is deterministic,
        // so landing on an operating point here is bitwise-identical to
        // constructing a fresh backend at it (the degradation ladder's
        // correctness contract).
        let tile = point.tile.unwrap_or(self.model.tile);
        self.prepare(tile, point.rate, point.quant)?;
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::synth::{synth_testset, synth_weights};
    use crate::infer::testutil::{mini_dims, zero_ff_tiles};
    use crate::qos::AsrEvaluator;

    fn mini_evaluator(n_utts: usize) -> (AsrEvaluator, NativeBackend) {
        let dims = mini_dims();
        let w = synth_weights(&dims, 21);
        let ts = synth_testset(&w, n_utts, 1).unwrap();
        let params = w.to_bundle();
        let meta = crate::qos::EvalMeta {
            n_blocks: dims.n_blocks,
            batch: 2,
            vocab: dims.vocab,
            blank: dims.ctc_blank,
            tile_hint: dims.tile,
        };
        let eval = AsrEvaluator::from_parts("native", params, &ts, &meta).unwrap();
        let backend = NativeBackend::new(w, 2).unwrap();
        (eval, backend)
    }

    #[test]
    fn baseline_wer_is_zero_on_teacher_labels() {
        let (eval, mut be) = mini_evaluator(5);
        let p = eval.evaluate_with(&mut be, 8, 0.0, Quant::Fp32).unwrap();
        assert_eq!(p.qos, 0.0, "dense FP32 must reproduce its own labels");
        assert_eq!(p.achieved_rate, 0.0);
    }

    #[test]
    fn qos_path_skips_recovered_tiles() {
        let (eval, mut be) = mini_evaluator(4);
        be.reset_stats();
        let p = eval.evaluate_with(&mut be, 8, 0.5, Quant::Int8).unwrap();
        assert!((p.achieved_rate - 0.5).abs() < 0.1);
        let st = be.stats();
        assert!(
            st.ff.tiles_skipped > 0,
            "recovered masks must skip pruned tiles: {st:?}"
        );
        // Recovered sparsity tracks the requested rate (random weights
        // have no naturally zero tiles).
        let frac = st.ff.tiles_skipped as f64
            / (st.ff.tiles_live + st.ff.tiles_skipped) as f64;
        assert!((frac - p.achieved_rate).abs() < 1e-9, "{frac} vs {}", p.achieved_rate);
        assert!(p.qos >= 0.0);
    }

    #[test]
    fn prepare_and_configure_agree() {
        // The direct pruning path (prepare) and the QoS bundle path
        // (prune-by-zeroing + mask recovery) must produce identical
        // log-probs for the same configuration — in both weight
        // formats (staging zeroes dead tiles before quantization, so
        // the INT8 scales agree too).
        let dims = mini_dims();
        let w = synth_weights(&dims, 23);
        let plan = global_prune(&ff_norms(&w, 8).unwrap(), 0.4);
        let mut wz = w.clone();
        zero_ff_tiles(&mut wz, &plan.masks, 8);
        let mut rng = crate::util::rng::Rng::new(6);
        let feats: Vec<f32> = (0..dims.seq_len * dims.input_dim)
            .map(|_| rng.normal() as f32 * 0.5)
            .collect();
        let pad = vec![1.0f32; dims.seq_len];

        for quant in [Quant::Fp32, Quant::Int8] {
            let mut direct = NativeBackend::new(w.clone(), 1).unwrap();
            direct.prepare(8, 0.4, quant).unwrap();
            let mut via_bundle = NativeBackend::new(w.clone(), 1).unwrap();
            via_bundle.configure(&wz.to_bundle(), 8, quant).unwrap();
            let a = direct.forward_batch(&feats, &pad, 1);
            let b = via_bundle.forward_batch(&feats, &pad, 1);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() <= 1e-6, "{quant:?}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn serve_backend_contract() {
        let dims = mini_dims();
        let w = synth_weights(&dims, 25);
        let mut be = NativeBackend::new(w, 3).unwrap();
        let man = be.manifest().clone();
        assert_eq!(man.args[0].shape, vec![3, dims.seq_len, dims.input_dim]);
        assert_eq!(man.model.batch, 3);
        assert_eq!(man.model.ctc_blank, dims.ctc_blank as i64);
        let feats = Tensor::zeros(&man.args[0].shape, DType::F32);
        let pad = Tensor::zeros(&man.args[1].shape, DType::F32);
        let out = be.execute("native_asr_encoder", &[feats, pad]).unwrap();
        assert_eq!(out.shape, vec![3, dims.seq_len, dims.vocab]);
        // CTC log-probs: every frame is a normalized distribution.
        let lp = out.f32s();
        let row: f32 = lp[..dims.vocab].iter().map(|v| v.exp()).sum();
        assert!((row - 1.0).abs() < 1e-4, "sum {row}");
        // Wrong arity is rejected via the manifest contract.
        let only = Tensor::zeros(&man.args[0].shape, DType::F32);
        assert!(be.execute("native_asr_encoder", &[only]).is_err());
    }

    #[test]
    fn per_channel_int8_qos_no_worse_than_per_tensor() {
        // Satellite contract: at the same pruning rate, per-channel INT8
        // scales keep the model at least as close to the FP32 reference
        // as per-tensor scales do — measured as mean |Δlog-prob| over a
        // teacher-labeled test set — and the decoded QoS (WER) does not
        // degrade beyond granularity noise.
        use crate::qos::{ctc_greedy, token_error_rate};

        let dims = mini_dims();
        let w = synth_weights(&dims, 31);
        let ts = synth_testset(&w, 8, 3).unwrap();
        let n = 8usize;
        let (t, v) = (dims.seq_len, dims.vocab);
        let feats = ts.get("feats").unwrap().f32s();
        let feat_len = ts.get("feat_len").unwrap().i32s();
        let labels = ts.get("labels").unwrap();
        let lmax = labels.shape[1];
        let lvals = labels.i32s();
        let label_len = ts.get("label_len").unwrap().i32s();
        let refs: Vec<Vec<i32>> = (0..n)
            .map(|i| lvals[i * lmax..i * lmax + label_len[i] as usize].to_vec())
            .collect();
        let mut pad = vec![0.0f32; n * t];
        for (i, l) in feat_len.iter().enumerate() {
            for tt in 0..*l as usize {
                pad[i * t + tt] = 1.0;
            }
        }

        let run = |per_channel: bool, quant: Quant, rate: f64| -> Vec<f32> {
            let mut be = NativeBackend::new(w.clone(), n).unwrap();
            be.set_per_channel(per_channel);
            be.prepare(dims.tile, rate, quant).unwrap();
            be.forward_batch(&feats, &pad, n)
        };
        let reference = run(false, Quant::Fp32, 0.25);
        let pt = run(false, Quant::Int8, 0.25);
        let pc = run(true, Quant::Int8, 0.25);
        let mad = |lp: &[f32]| -> f64 {
            lp.iter()
                .zip(&reference)
                .map(|(a, b)| (a - b).abs() as f64)
                .sum::<f64>()
                / lp.len() as f64
        };
        let (dev_pt, dev_pc) = (mad(&pt), mad(&pc));
        assert!(
            dev_pc <= dev_pt,
            "per-channel dev {dev_pc} must not exceed per-tensor {dev_pt}"
        );
        let wer = |lp: &[f32]| -> f64 {
            let hyps: Vec<Vec<i32>> = (0..n)
                .map(|i| {
                    ctc_greedy(
                        &lp[i * t * v..(i + 1) * t * v],
                        feat_len[i] as usize,
                        v,
                        dims.ctc_blank,
                    )
                })
                .collect();
            token_error_rate(&refs, &hyps)
        };
        let (wer_pt, wer_pc) = (wer(&pt), wer(&pc));
        assert!(
            wer_pc <= wer_pt + 0.05,
            "per-channel WER {wer_pc} vs per-tensor {wer_pt}"
        );
    }

    fn mini_mt_backend(batch: usize) -> NativeBackend {
        use crate::infer::decoder::testutil::mini_dec_dims;
        use crate::infer::synth::synth_decoder_weights;
        let dims = ModelDims {
            token_input: true,
            ctc_blank: -1,
            ..mini_dims()
        };
        let enc = synth_weights(&dims, 43);
        let dec = synth_decoder_weights(&mini_dec_dims(), 43);
        NativeBackend::new_mt(enc, dec, batch).unwrap()
    }

    fn mt_batch(be: &NativeBackend, n: usize, seed: u64) -> (Vec<i32>, Vec<usize>) {
        let dims = *be.dims();
        let mut rng = crate::util::rng::Rng::new(seed);
        let t = dims.seq_len;
        let mut src = vec![0i32; n * t];
        let mut lens = Vec::with_capacity(n);
        for u in 0..n {
            let len = t / 2 + rng.index(t / 2);
            for tok in src[u * t..u * t + len].iter_mut() {
                *tok = rng.index(dims.vocab) as i32;
            }
            lens.push(len);
        }
        (src, lens)
    }

    #[test]
    fn mt_joint_prune_skips_decoder_tiles_too() {
        let mut be = mini_mt_backend(2);
        let plan = be.prepare(8, 0.5, Quant::Int8).unwrap();
        assert!((plan.achieved_rate - 0.5).abs() < 0.1);
        let enc_sp = be.model().ff_sparsity();
        let dec_sp = be.dec_model().unwrap().ff_sparsity();
        assert!(enc_sp > 0.0, "encoder ff must lose tiles");
        assert!(dec_sp > 0.0, "decoder ff must lose tiles");
        let (src, lens) = mt_batch(&be, 3, 1);
        be.reset_stats();
        let hyps = be.translate(&src, &lens).unwrap();
        assert_eq!(hyps.len(), 3);
        let ds = be.decode_stats();
        assert!(ds.ff.tiles_skipped > 0, "decode path must skip pruned tiles");
        assert!(ds.steps > 0);
        assert_eq!(ds.utterances, 3);
        // Cross-K/V ran weight-stationary: one programming pass per
        // live tile for the whole batch.
        assert!(ds.cross_kv.timing.prog_words > 0);
    }

    #[test]
    fn batched_translate_bitwise_equals_batch_of_one() {
        // Satellite: the batched cross-attention K/V precompute keeps
        // per-utterance bitwise exactness, in both weight formats.
        for quant in [Quant::Fp32, Quant::Int8] {
            let mut be = mini_mt_backend(4);
            be.prepare(8, 0.3, quant).unwrap();
            let (src, lens) = mt_batch(&be, 4, 2);
            let batched = be.translate(&src, &lens).unwrap();
            let kv_batched = be.decode_stats().cross_kv.timing;

            let mut single = mini_mt_backend(4);
            single.prepare(8, 0.3, quant).unwrap();
            let t = be.dims().seq_len;
            for u in 0..4usize {
                let one = single
                    .translate(&src[u * t..(u + 1) * t], &lens[u..u + 1])
                    .unwrap();
                assert_eq!(batched[u], one[0], "{quant:?}: utterance {u}");
            }
            // TileTiming::batched accounting: streaming scales with the
            // batch, tile programming is charged once instead of four
            // times — the weight-stationary reuse win.
            let kv_single = single.decode_stats().cross_kv.timing;
            assert_eq!(kv_batched.in_words, kv_single.in_words, "{quant:?}");
            assert_eq!(kv_batched.macs, kv_single.macs, "{quant:?}");
            assert_eq!(
                4 * kv_batched.prog_words,
                kv_single.prog_words,
                "{quant:?}: batched K/V programs each tile once per batch"
            );
        }
    }

    #[test]
    fn continuous_translate_bitwise_equals_sequential_translate() {
        // Tentpole integration contract at backend scope: the
        // continuous iteration-level scheduler produces exactly the
        // sequential per-utterance translations, in both weight
        // formats, while packing each step's GEMVs into shared panels.
        for quant in [Quant::Fp32, Quant::Int8] {
            let mut seq = mini_mt_backend(4);
            seq.prepare(8, 0.3, quant).unwrap();
            let (src, lens) = mt_batch(&seq, 6, 5);
            let want = seq.translate(&src, &lens).unwrap();

            let mut cont = mini_mt_backend(4);
            cont.prepare(8, 0.3, quant).unwrap();
            cont.reset_stats();
            let (got, schedule) = cont.translate_continuous(&src, &lens, 3).unwrap();
            assert_eq!(got, want, "{quant:?}: continuous == sequential");
            // The schedule is the decode accounting's ground truth:
            // its sum is the step count, its entries the panel fills.
            let ds = cont.decode_stats();
            assert_eq!(ds.steps, schedule.iter().sum::<usize>(), "{quant:?}");
            assert_eq!(ds.utterances, 6, "{quant:?}");
            assert!(schedule[0] == 3, "{quant:?}: starts with a full panel");
            assert!(schedule.iter().all(|&k| k >= 1 && k <= 3), "{quant:?}");
            // Cross-K/V precompute ran batched up front, charged once.
            assert!(ds.cross_kv.timing.prog_words > 0, "{quant:?}");
        }
    }

    #[test]
    fn decode_join_and_step_drive_a_session_like_translate_continuous() {
        // The serving-loop surface: joining utterances in two waves and
        // stepping manually produces the same per-utterance outputs as
        // the one-shot continuous path (and as sequential decode) —
        // joins between steps do not disturb in-flight slots.
        let mut be = mini_mt_backend(4);
        be.prepare(8, 0.3, Quant::Int8).unwrap();
        let (src, lens) = mt_batch(&be, 4, 7);
        let want = be.translate(&src, &lens).unwrap();

        let t = be.dims().seq_len;
        let mut cd = ContinuousDecoder::new(2);
        let mut got: Vec<Vec<i32>> = vec![Vec::new(); 4];
        let mut joined = 0usize;
        while joined < 4 || cd.live() > 0 {
            let free = cd.max_slots() - cd.live();
            let take = free.min(4 - joined);
            if take > 0 {
                let ids: Vec<u64> = (joined..joined + take).map(|u| u as u64).collect();
                be.decode_join(
                    &mut cd,
                    &ids,
                    &src[joined * t..(joined + take) * t],
                    &lens[joined..joined + take],
                )
                .unwrap();
                joined += take;
            }
            for fin in be.decode_step(&mut cd).unwrap() {
                got[fin.id as usize] = fin.tokens;
            }
        }
        assert_eq!(got, want, "join/step session == sequential translate");
        assert_eq!(cd.stats.utterances, 4);
    }

    #[test]
    fn mt_prepare_and_configure_agree() {
        // The direct pruning path and the QoS bundle path (zeroed tiles
        // + mask recovery on encoder AND decoder) produce identical
        // translations.
        use crate::infer::decoder::testutil::zero_dec_ff_tiles;
        let be0 = mini_mt_backend(1);
        let enc = be0.weights().clone();
        let dec = be0.dec_master.clone().unwrap();
        let mut norms = ff_norms(&enc, 8).unwrap();
        let enc_gemms = norms.len();
        norms.extend(dec.ff_norms(8).unwrap());
        let plan = global_prune(&norms, 0.4);
        let mut encz = enc.clone();
        zero_ff_tiles(&mut encz, &plan.masks[..enc_gemms], 8);
        let mut decz = dec.clone();
        zero_dec_ff_tiles(&mut decz, &plan.masks[enc_gemms..], 8);
        let mut bundle = encz.to_bundle();
        decz.append_to_bundle(&mut bundle);

        let (src, lens) = mt_batch(&be0, 2, 3);
        for quant in [Quant::Fp32, Quant::Int8] {
            let mut direct = NativeBackend::new_mt(enc.clone(), dec.clone(), 1).unwrap();
            direct.prepare(8, 0.4, quant).unwrap();
            let mut via_bundle = NativeBackend::new_mt(enc.clone(), dec.clone(), 1).unwrap();
            via_bundle.configure(&bundle, 8, quant).unwrap();
            let a = direct.translate(&src, &lens).unwrap();
            let b = via_bundle.translate(&src, &lens).unwrap();
            assert_eq!(a, b, "{quant:?}");
        }
    }

    #[test]
    fn mt_manifest_and_serve_contract() {
        let mut be = mini_mt_backend(2);
        let man = be.manifest().clone();
        assert_eq!(man.name, "native_mt_encoder");
        assert_eq!(man.args.len(), 1);
        assert_eq!(man.args[0].shape, vec![2, be.dims().seq_len]);
        assert!(man.model.token_input);
        let src = Tensor::zeros(&man.args[0].shape, DType::I32);
        let out = be.execute("native_mt_encoder", &[src]).unwrap();
        assert_eq!(out.shape, vec![2, be.dims().seq_len, be.dims().vocab]);
    }

    #[test]
    fn recover_masks_roundtrips_prune_plan() {
        let dims = mini_dims();
        let w = synth_weights(&dims, 27);
        let plan = global_prune(&ff_norms(&w, 8).unwrap(), 0.3);
        let mut wz = w.clone();
        zero_ff_tiles(&mut wz, &plan.masks, 8);
        let rec = recover_masks(&wz, 8).unwrap();
        assert_eq!(rec, plan.masks);
    }

    #[test]
    fn int8_qos_matches_fp32_on_fake_quantized_bundle() {
        // The evaluator fake-quantizes the bundle for INT8; running that
        // bundle through the FP32 kernels or re-packing it for the INT8
        // kernels must give the same hypotheses (kernel equivalence at
        // QoS scope).
        let (eval, mut be) = mini_evaluator(4);
        let a = eval.evaluate_with(&mut be, 8, 0.2, Quant::Int8).unwrap();
        // Same configuration, but force the backend to stay FP32 over
        // the fake-quantized params by evaluating through a wrapper that
        // rewrites quant.
        struct ForceFp32<'a>(&'a mut NativeBackend);
        impl crate::qos::QosBackend for ForceFp32<'_> {
            fn configure(&mut self, p: &Bundle, tile: usize, _q: Quant) -> Result<()> {
                self.0.configure(p, tile, Quant::Fp32)
            }
            fn run_asr(&mut self, f: &[f32], p: &[f32], b: usize) -> Result<Vec<f32>> {
                self.0.run_asr(f, p, b)
            }
            fn run_mt(&mut self, s: &[i32], b: usize) -> Result<Vec<f32>> {
                self.0.run_mt(s, b)
            }
        }
        let mut forced = ForceFp32(&mut be);
        let b = eval.evaluate_with(&mut forced, 8, 0.2, Quant::Int8).unwrap();
        assert_eq!(a.qos, b.qos, "kernel INT8 vs fake-quant FP32 WER");
    }

    #[test]
    fn chunk_sizes_cover_and_balance() {
        // 2x-oversubscribed chunking for the work queue: min(batch,
        // 2 * threads) contiguous near-equal chunks, a single chunk on
        // the single-worker path.
        assert_eq!(NativeBackend::chunk_sizes(5, 2), vec![2, 1, 1, 1]);
        assert_eq!(NativeBackend::chunk_sizes(4, 4), vec![1, 1, 1, 1]);
        assert_eq!(NativeBackend::chunk_sizes(2, 4), vec![1, 1], "never empty chunks");
        assert_eq!(NativeBackend::chunk_sizes(7, 3), vec![2, 1, 1, 1, 1, 1]);
        assert_eq!(NativeBackend::chunk_sizes(20, 4), vec![3, 3, 3, 3, 2, 2, 2, 2]);
        assert_eq!(
            NativeBackend::chunk_sizes(6, 1),
            vec![6],
            "one worker keeps the batch-level accounting path"
        );
        assert_eq!(NativeBackend::chunk_sizes(1, 8), vec![1]);
        for (batch, threads) in [(5, 2), (7, 3), (20, 4), (3, 8)] {
            let chunks = NativeBackend::chunk_sizes(batch, threads);
            assert_eq!(chunks.iter().sum::<usize>(), batch, "{batch}/{threads} covers");
            assert!(chunks.iter().all(|&c| c > 0));
        }
    }

    /// A ragged batch of synthetic features over the mini model.
    fn ragged(dims: &ModelDims, batch: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let t = dims.seq_len;
        let feats: Vec<f32> = (0..batch * t * dims.input_dim)
            .map(|_| rng.normal() as f32 * 0.5)
            .collect();
        let mut pad = vec![0.0f32; batch * t];
        for u in 0..batch {
            let len = 1 + rng.index(t);
            for p in pad[u * t..u * t + len].iter_mut() {
                *p = 1.0;
            }
        }
        (feats, pad)
    }

    #[test]
    fn prop_sharded_forward_batch_bitwise_equals_single_thread() {
        // The work-stealing exactness contract: chunking a flushed
        // batch across an atomic-cursor worker pool must not change a
        // single output bit — ragged tails, both weight formats, any
        // thread count, regardless of which worker claims which chunk.
        crate::util::prop::check(
            "sharded == single-thread forward_batch",
            10,
            |rng: &mut crate::util::rng::Rng| {
                let dims = mini_dims();
                let w = crate::infer::synth::synth_weights(&dims, 77);
                let batch = rng.index(6) + 1;
                let threads = [2usize, 3, 4, 8][rng.index(4)];
                let quant = if rng.chance(0.5) { Quant::Fp32 } else { Quant::Int8 };
                let (feats, pad) = ragged(&dims, batch, 100 + rng.index(1000) as u64);
                let mut single = NativeBackend::new(w.clone(), batch).unwrap();
                single.prepare(8, 0.4, quant).unwrap();
                let a = single.forward_batch(&feats, &pad, batch);
                let mut sharded = NativeBackend::new(w, batch).unwrap();
                sharded.prepare(8, 0.4, quant).unwrap();
                sharded.set_threads(threads);
                let b = sharded.forward_batch(&feats, &pad, batch);
                (
                    a == b,
                    format!("batch={batch} threads={threads} {quant:?}"),
                )
            },
        );
    }

    #[test]
    fn sharded_stats_sum_per_shard_batched_accounting() {
        // Functional == analytic under work stealing: a batch of 5 over
        // 2 workers splits into chunks of 2 + 1 + 1 + 1 (claim order
        // races, chunk composition does not), and the merged ff
        // statistics must charge exactly the analytic batched cost of
        // each chunk, summed.
        use crate::model::{GemmKind, GemmShape};
        use crate::sysim::engine::gemm_on_array_batched;
        use crate::sysim::SimParams;
        use crate::systolic::ArrayConfig;

        let dims = mini_dims();
        let w = synth_weights(&dims, 81);
        let mut be = NativeBackend::new(w, 5).unwrap();
        let plan = be.prepare(8, 0.5, Quant::Int8).unwrap();
        be.set_threads(2);
        assert_eq!(NativeBackend::chunk_sizes(5, 2), vec![2, 1, 1, 1]);
        let t = dims.seq_len;
        let (feats, pad) = ragged(&dims, 5, 9);
        be.reset_stats();
        let lp = be.forward_batch(&feats, &pad, 5);
        assert_eq!(lp.len(), 5 * t * dims.vocab);
        let st = *be.stats();
        assert_eq!(st.utterances, 5);

        let cfg = ArrayConfig::square(8, Quant::Int8);
        let p = SimParams::default();
        let (d, f) = (dims.d_model, dims.d_ff);
        let (mut macs, mut bus, mut cycles) = (0u64, 0u64, 0u64);
        for i in 0..dims.n_blocks {
            let shapes = [
                (GemmShape { m: t, k: d, n: f, kind: GemmKind::FeedForward }, 2 * i),
                (GemmShape { m: t, k: f, n: d, kind: GemmKind::FeedForward }, 2 * i + 1),
            ];
            for (g, mi) in shapes {
                for chunk in [2usize, 1, 1, 1] {
                    let c = gemm_on_array_batched(&g, &cfg, &p, Some(&plan.masks[mi]), chunk);
                    macs += c.counts.macs;
                    bus += c.counts.bus_words;
                    cycles += c.counts.array_busy_cycles;
                }
            }
        }
        assert_eq!(st.ff.timing.macs as u64, macs);
        assert_eq!(st.ff.timing.total_words() as u64, bus);
        assert_eq!(st.ff.timing.array_cycles as u64, cycles);
    }

    #[test]
    fn execute_rows_serves_exact_dynamic_batches() {
        // The any-batch serving contract: execute_rows runs exactly the
        // rows it is handed, bitwise equal to forward_batch, and
        // rejects mis-sized arguments.
        use crate::data::DType;
        let dims = mini_dims();
        let w = synth_weights(&dims, 83);
        let mut be = NativeBackend::new(w, 4).unwrap();
        assert!(ServeBackend::any_batch(&be));
        let (t, f) = (dims.seq_len, dims.input_dim);
        let (feats, pad) = ragged(&dims, 3, 15);
        let ft = Tensor::from_f32(&[3, t, f], &feats);
        let pt = Tensor::from_f32(&[3, t], &pad);
        let out = be
            .execute_rows("native_asr_encoder", &[ft.clone(), pt.clone()], 3)
            .unwrap();
        assert_eq!(out.shape, vec![3, t, dims.vocab]);
        assert_eq!(be.stats().utterances, 3, "exactly the queued rows ran");
        let want = be.forward_batch(&feats, &pad, 3);
        assert_eq!(out.f32s(), want, "bitwise equal to forward_batch");
        // Row-count mismatch is rejected.
        assert!(be.execute_rows("native_asr_encoder", &[ft, pt], 2).is_err());
        // Wrong dtype is rejected.
        let bad = Tensor::zeros(&[3, t, f], DType::I32);
        let pt2 = Tensor::zeros(&[3, t], DType::F32);
        assert!(be.execute_rows("native_asr_encoder", &[bad, pt2], 3).is_err());
    }

    #[test]
    fn contained_worker_panic_fails_only_its_shard() {
        // Satellite: a poisoned chunk must not kill the batcher OR the
        // stealing worker that claimed it — the worker catches the
        // unwind inside its claim loop and keeps draining the queue, so
        // only the poisoned chunk's utterances fail (zero-filled rows),
        // every other chunk's output stays bitwise intact, and the
        // backend keeps serving afterwards.
        const MARKER: f32 = 55.5;
        let dims = mini_dims();
        let (t, f, v) = (dims.seq_len, dims.input_dim, dims.vocab);
        let mut be = NativeBackend::new(synth_weights(&dims, 91), 4).unwrap();
        be.set_threads(2);
        be.set_panic_marker(Some(MARKER));
        let (mut feats, pad) = ragged(&dims, 4, 17);
        // Poison utterance 0: with single-utterance chunks, exactly one
        // chunk dies; its worker survives to claim later chunks (with 4
        // chunks over 2 workers the poisoned worker must pick up more
        // work for the batch to complete).
        feats[0] = MARKER;
        assert_eq!(NativeBackend::chunk_sizes(4, 2), vec![1, 1, 1, 1]);
        be.reset_stats();
        let mut out = Vec::new();
        let failed = be.forward_batch_contained(&feats, &pad, 4, &mut out);
        assert_eq!(failed, vec![0], "exactly the poisoned chunk fails");
        assert_eq!(out.len(), 4 * t * v, "output stays batch-aligned");
        assert!(out[..t * v].iter().all(|&x| x == 0.0), "failed rows zeroed");
        assert_eq!(be.stats().utterances, 3, "failed chunk charges nothing");

        // The surviving chunks are bitwise what a clean run produces.
        let mut reference = NativeBackend::new(synth_weights(&dims, 91), 4).unwrap();
        let want = reference.forward_batch(&feats[t * f..], &pad[t..], 3);
        assert_eq!(&out[t * v..], &want[..], "surviving chunks bitwise intact");

        // And the backend still serves a clean batch afterwards.
        let (clean, cpad) = ragged(&dims, 4, 18);
        let failed = be.forward_batch_contained(&clean, &cpad, 4, &mut out);
        assert!(failed.is_empty(), "clean flush after containment: {failed:?}");
        assert_eq!(be.stats().utterances, 7);
    }

    #[test]
    fn single_thread_panic_contained_and_stats_preserved() {
        // The single-runtime path catches the unwind too, and a failed
        // flush leaves the cumulative counters exactly as they were.
        const MARKER: f32 = 7.25;
        let dims = mini_dims();
        let (t, v) = (dims.seq_len, dims.vocab);
        let mut be = NativeBackend::new(synth_weights(&dims, 93), 2).unwrap();
        be.set_panic_marker(Some(MARKER));
        let (clean, cpad) = ragged(&dims, 2, 19);
        be.reset_stats();
        be.forward_batch(&clean, &cpad, 2);
        let before = *be.stats();
        assert_eq!(before.utterances, 2);

        let (mut feats, pad) = ragged(&dims, 2, 20);
        feats[0] = MARKER;
        let mut out = Vec::new();
        let failed = be.forward_batch_contained(&feats, &pad, 2, &mut out);
        assert_eq!(failed, vec![0, 1], "single runtime fails the whole flush");
        assert_eq!(out.len(), 2 * t * v);
        assert!(out.iter().all(|&x| x == 0.0));
        assert_eq!(*be.stats(), before, "failed flush charges nothing");

        // Still serving.
        let failed = be.forward_batch_contained(&clean, &cpad, 2, &mut out);
        assert!(failed.is_empty());
        assert_eq!(be.stats().utterances, 4);
    }

    #[test]
    fn set_operating_point_restages_like_prepare() {
        // The ladder contract: stepping the live backend to an
        // operating point is bitwise what prepare() at that point gives.
        use crate::coordinator::resilience::OperatingPoint;
        let dims = mini_dims();
        let (feats, pad) = ragged(&dims, 2, 21);
        let mut stepped = NativeBackend::new(synth_weights(&dims, 95), 2).unwrap();
        let restaged =
            ServeBackend::set_operating_point(&mut stepped, &OperatingPoint::new(0.5, Quant::Int8))
                .unwrap();
        assert!(restaged, "native backend supports the ladder");
        let mut direct = NativeBackend::new(synth_weights(&dims, 95), 2).unwrap();
        direct.prepare(dims.tile, 0.5, Quant::Int8).unwrap();
        assert_eq!(
            stepped.forward_batch(&feats, &pad, 2),
            direct.forward_batch(&feats, &pad, 2),
            "ladder step bitwise equals standalone prepare"
        );
    }
}
