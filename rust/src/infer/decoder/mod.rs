//! Native transformer decoder — the autoregressive half of the MT
//! engine, the decode-side twin of [`super::encoder`].
//!
//! A pre-LN decoder block is causal masked self-attention,
//! encoder-decoder cross-attention, and the SASP feed-forward pair; the
//! weight GEMMs (six `[d, d]` attention projections per block plus the
//! pruned `w1`/`w2` pair) run on the same pruned-tile kernels as the
//! encoder ([`super::gemm`]), so the FP32 and [`crate::arith::SignMag8`]
//! formats carry the identical oracle relationship, and every executed
//! tile is accounted with the same closed-form
//! [`crate::systolic::TileTiming`] the analytic engine charges —
//! including the decode regime's skinny `[1, d]` GEMVs, where tile
//! occupancy shrinks to a single activation row per pass
//! ([`crate::sysim::engine::gemm_on_array_decode`] is the analytic
//! counterpart).
//!
//! - [`mod@self`] — decoder dimensions, FP32 weight containers with the
//!   `dec.*` bundle naming (so one `tensorfile` bundle carries encoder
//!   plus decoder parameters through the QoS prune/quantize pipeline),
//!   and [`PreparedDecoder`], the staged (tile, quant, masks)
//!   configuration.
//! - [`forward`] — [`DecoderForward`]: the incremental KV-cache runtime
//!   (one step per generated token, bitwise identical to full-prefix
//!   recompute), greedy BOS→EOS generation, and the per-scope
//!   [`DecodeStats`] accounting with cross-attention K/V computed once
//!   per utterance and reused every step.
//! - [`continuous`] — [`ContinuousDecoder`]: the iteration-level
//!   (continuous) batched scheduler that steps many in-flight decodes
//!   in lockstep, batching each step's per-token GEMVs into `[k, d]`
//!   weight-stationary panels with slot join/leave between steps —
//!   bitwise identical per utterance to [`DecoderForward`] greedy
//!   decode, panel-batched in the accounting.

pub mod continuous;
pub mod forward;

pub use continuous::{ContinuousDecoder, Finished};
pub use forward::{DecodeStats, DecoderForward};

use anyhow::{ensure, Result};

use crate::data::{Bundle, Tensor};
use crate::pruning::{tile_l1_norms, TileNorms};
use crate::sysim::TileMask;
use crate::systolic::Quant;

use super::encoder::{kernel_weight, masked_kernel_weight, soft_weight};
use super::gemm::Linear;
use super::ops;

/// Shape hyper-parameters of one decoder stack. `d_model`, `n_heads`
/// and `vocab` must match the encoder feeding it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecoderDims {
    /// Target vocabulary (shares the encoder's token space, including
    /// BOS/EOS).
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_blocks: usize,
    /// Maximum generated target length (the position-table size; BOS
    /// occupies position 0).
    pub max_len: usize,
    /// Default SASP tile.
    pub tile: usize,
    /// Begin-of-sentence token seeding generation.
    pub bos: i32,
    /// End-of-sentence token stopping generation.
    pub eos: i32,
}

impl DecoderDims {
    /// The tiny-MT decoder stand-in paired with
    /// [`super::encoder::ModelDims::tiny_mt`].
    pub fn tiny_mt() -> Self {
        DecoderDims {
            vocab: 32,
            d_model: 64,
            n_heads: 4,
            d_ff: 256,
            n_blocks: 2,
            max_len: 24,
            tile: 8,
            bos: 1,
            eos: 2,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Whether `tile` is a legal SASP tile for these dimensions.
    pub fn tile_ok(&self, tile: usize) -> bool {
        tile > 0 && self.d_model % tile == 0 && self.d_ff % tile == 0
    }
}

/// One decoder block's FP32 weights.
#[derive(Clone, Debug)]
pub struct DecoderBlockWeights {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    /// Causal self-attention projections.
    pub sq: Vec<f32>,
    pub sk: Vec<f32>,
    pub sv: Vec<f32>,
    pub so: Vec<f32>,
    pub lnx_g: Vec<f32>,
    pub lnx_b: Vec<f32>,
    /// Encoder-decoder cross-attention projections.
    pub xq: Vec<f32>,
    pub xk: Vec<f32>,
    pub xv: Vec<f32>,
    pub xo: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

/// The full FP32 weight set of one decoder stack.
#[derive(Clone, Debug)]
pub struct DecoderWeights {
    pub dims: DecoderDims,
    /// Target token embedding `[vocab, d_model]`.
    pub emb: Vec<f32>,
    pub blocks: Vec<DecoderBlockWeights>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
    /// Vocabulary head `[d_model, vocab]`.
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
}

fn take(b: &Bundle, name: &str, shape: &[usize]) -> Result<Vec<f32>> {
    let t = b.require(name)?;
    ensure!(
        t.shape == shape,
        "{name}: shape {:?} != expected {:?}",
        t.shape,
        shape
    );
    Ok(t.f32s())
}

impl DecoderWeights {
    /// Load from a bundle carrying the `dec.*` entries (the layout
    /// [`Self::append_to_bundle`] writes).
    pub fn from_bundle(dims: DecoderDims, b: &Bundle) -> Result<Self> {
        let (d, f, v) = (dims.d_model, dims.d_ff, dims.vocab);
        let mut blocks = Vec::with_capacity(dims.n_blocks);
        for i in 0..dims.n_blocks {
            let p = format!("dec.block{i}.");
            blocks.push(DecoderBlockWeights {
                ln1_g: take(b, &format!("{p}ln1.g"), &[d])?,
                ln1_b: take(b, &format!("{p}ln1.b"), &[d])?,
                sq: take(b, &format!("{p}self.wq"), &[d, d])?,
                sk: take(b, &format!("{p}self.wk"), &[d, d])?,
                sv: take(b, &format!("{p}self.wv"), &[d, d])?,
                so: take(b, &format!("{p}self.wo"), &[d, d])?,
                lnx_g: take(b, &format!("{p}lnx.g"), &[d])?,
                lnx_b: take(b, &format!("{p}lnx.b"), &[d])?,
                xq: take(b, &format!("{p}cross.wq"), &[d, d])?,
                xk: take(b, &format!("{p}cross.wk"), &[d, d])?,
                xv: take(b, &format!("{p}cross.wv"), &[d, d])?,
                xo: take(b, &format!("{p}cross.wo"), &[d, d])?,
                ln2_g: take(b, &format!("{p}ln2.g"), &[d])?,
                ln2_b: take(b, &format!("{p}ln2.b"), &[d])?,
                w1: take(b, &format!("{p}ff.w1"), &[d, f])?,
                b1: take(b, &format!("{p}ff.b1"), &[f])?,
                w2: take(b, &format!("{p}ff.w2"), &[f, d])?,
                b2: take(b, &format!("{p}ff.b2"), &[d])?,
            });
        }
        Ok(DecoderWeights {
            emb: take(b, "dec.emb.w", &[v, d])?,
            blocks,
            lnf_g: take(b, "dec.ln_f.g", &[d])?,
            lnf_b: take(b, "dec.ln_f.b", &[d])?,
            head_w: take(b, "dec.head.w", &[d, v])?,
            head_b: take(b, "dec.head.b", &[v])?,
            dims,
        })
    }

    /// Append the `dec.*` entries to `b` (alongside an encoder's
    /// entries — one bundle per MT model).
    pub fn append_to_bundle(&self, b: &mut Bundle) {
        let (d, f, v) = (self.dims.d_model, self.dims.d_ff, self.dims.vocab);
        b.insert("dec.emb.w", Tensor::from_f32(&[v, d], &self.emb));
        for (i, blk) in self.blocks.iter().enumerate() {
            let p = format!("dec.block{i}.");
            b.insert(&format!("{p}ln1.g"), Tensor::from_f32(&[d], &blk.ln1_g));
            b.insert(&format!("{p}ln1.b"), Tensor::from_f32(&[d], &blk.ln1_b));
            b.insert(&format!("{p}self.wq"), Tensor::from_f32(&[d, d], &blk.sq));
            b.insert(&format!("{p}self.wk"), Tensor::from_f32(&[d, d], &blk.sk));
            b.insert(&format!("{p}self.wv"), Tensor::from_f32(&[d, d], &blk.sv));
            b.insert(&format!("{p}self.wo"), Tensor::from_f32(&[d, d], &blk.so));
            b.insert(&format!("{p}lnx.g"), Tensor::from_f32(&[d], &blk.lnx_g));
            b.insert(&format!("{p}lnx.b"), Tensor::from_f32(&[d], &blk.lnx_b));
            b.insert(&format!("{p}cross.wq"), Tensor::from_f32(&[d, d], &blk.xq));
            b.insert(&format!("{p}cross.wk"), Tensor::from_f32(&[d, d], &blk.xk));
            b.insert(&format!("{p}cross.wv"), Tensor::from_f32(&[d, d], &blk.xv));
            b.insert(&format!("{p}cross.wo"), Tensor::from_f32(&[d, d], &blk.xo));
            b.insert(&format!("{p}ln2.g"), Tensor::from_f32(&[d], &blk.ln2_g));
            b.insert(&format!("{p}ln2.b"), Tensor::from_f32(&[d], &blk.ln2_b));
            b.insert(&format!("{p}ff.w1"), Tensor::from_f32(&[d, f], &blk.w1));
            b.insert(&format!("{p}ff.b1"), Tensor::from_f32(&[f], &blk.b1));
            b.insert(&format!("{p}ff.w2"), Tensor::from_f32(&[f, d], &blk.w2));
            b.insert(&format!("{p}ff.b2"), Tensor::from_f32(&[d], &blk.b2));
        }
        b.insert("dec.ln_f.g", Tensor::from_f32(&[d], &self.lnf_g));
        b.insert("dec.ln_f.b", Tensor::from_f32(&[d], &self.lnf_b));
        b.insert("dec.head.w", Tensor::from_f32(&[d, v], &self.head_w));
        b.insert("dec.head.b", Tensor::from_f32(&[v], &self.head_b));
    }

    /// The decoder's prunable feed-forward names, in execution order —
    /// the `dec.*` continuation of the encoder's `block{i}.ff.*` list.
    pub fn ff_names(n_blocks: usize) -> Vec<String> {
        (0..n_blocks)
            .flat_map(|i| [format!("dec.block{i}.ff.w1"), format!("dec.block{i}.ff.w2")])
            .collect()
    }

    /// Per-feed-forward-GEMM tile L1 norms (the pruning statistic).
    pub fn ff_norms(&self, tile: usize) -> Result<Vec<TileNorms>> {
        ensure!(self.dims.tile_ok(tile), "tile {tile} does not divide the decoder");
        let (d, f) = (self.dims.d_model, self.dims.d_ff);
        let mut out = Vec::with_capacity(2 * self.dims.n_blocks);
        for blk in &self.blocks {
            out.push(tile_l1_norms(&Tensor::from_f32(&[d, f], &blk.w1), tile));
            out.push(tile_l1_norms(&Tensor::from_f32(&[f, d], &blk.w2), tile));
        }
        Ok(out)
    }

    /// Recover feed-forward tile masks from (possibly) tile-zeroed
    /// weights — the decode-side counterpart of
    /// [`super::backend::recover_masks`].
    pub fn recover_masks(&self, tile: usize) -> Result<Vec<TileMask>> {
        Ok(self
            .ff_norms(tile)?
            .iter()
            .map(|tn| TileMask {
                kt: tn.kt,
                nt: tn.nt,
                live: tn.norms.iter().map(|v| *v != 0.0).collect(),
            })
            .collect())
    }
}

/// One decoder block staged for execution.
#[derive(Clone, Debug)]
pub struct PreparedDecoderBlock {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub sq: Linear,
    pub sk: Linear,
    pub sv: Linear,
    pub so: Linear,
    pub lnx_g: Vec<f32>,
    pub lnx_b: Vec<f32>,
    pub xq: Linear,
    pub xk: Linear,
    pub xv: Linear,
    pub xo: Linear,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub w1: Linear,
    pub b1: Vec<f32>,
    pub w2: Linear,
    pub b2: Vec<f32>,
    pub mask1: TileMask,
    pub mask2: TileMask,
}

/// A decoder staged for inference at one (tile, quant, masks)
/// configuration — the decode-side twin of
/// [`super::encoder::PreparedModel`].
#[derive(Clone, Debug)]
pub struct PreparedDecoder {
    pub dims: DecoderDims,
    pub tile: usize,
    pub quant: Quant,
    /// Token embedding (software-read; fake-quantized in INT8 mode,
    /// matching the PTQ set of `qos::eval`).
    pub emb: Vec<f32>,
    pub blocks: Vec<PreparedDecoderBlock>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
    /// Precomputed `max_len x d_model` target position table.
    pub pe: Vec<f32>,
    /// Whether INT8 weights were staged with per-output-channel scales.
    pub per_channel: bool,
}

impl PreparedDecoder {
    /// Stage `w` for execution. `masks` supplies one [`TileMask`] per
    /// feed-forward GEMM in execution order (`[w1_0, w2_0, w1_1, ...]`);
    /// `None` runs dense.
    pub fn new(
        w: &DecoderWeights,
        tile: usize,
        quant: Quant,
        masks: Option<&[TileMask]>,
    ) -> Result<Self> {
        Self::new_with(w, tile, quant, masks, false)
    }

    /// [`Self::new`] with the per-output-channel INT8 scale flag — the
    /// same per-column LUT staging as the encoder's
    /// [`super::encoder::PreparedModel::new_with`], so decoder layers
    /// participate in the per-channel PTQ satellite too.
    pub fn new_with(
        w: &DecoderWeights,
        tile: usize,
        quant: Quant,
        masks: Option<&[TileMask]>,
        per_channel: bool,
    ) -> Result<Self> {
        let dims = w.dims;
        let (d, f) = (dims.d_model, dims.d_ff);
        ensure!(dims.tile_ok(tile), "tile {tile} does not divide {d}x{f}");
        ensure!(dims.max_len > 0, "max_len must be positive");
        ensure!(
            (dims.bos as usize) < dims.vocab && (dims.eos as usize) < dims.vocab,
            "BOS/EOS must be in-vocabulary"
        );
        if let Some(ms) = masks {
            ensure!(
                ms.len() == 2 * dims.n_blocks,
                "expected {} ff masks, got {}",
                2 * dims.n_blocks,
                ms.len()
            );
        }
        let (kt1, nt1) = (d / tile, f / tile);
        let mut blocks = Vec::with_capacity(dims.n_blocks);
        for (i, blk) in w.blocks.iter().enumerate() {
            let mask1 = match masks {
                Some(ms) => ms[2 * i].clone(),
                None => TileMask::full(kt1, nt1),
            };
            let mask2 = match masks {
                Some(ms) => ms[2 * i + 1].clone(),
                None => TileMask::full(nt1, kt1),
            };
            ensure!(
                (mask1.kt, mask1.nt) == (kt1, nt1)
                    && (mask2.kt, mask2.nt) == (nt1, kt1),
                "decoder block {i}: ff mask grid does not match tile {tile}"
            );
            blocks.push(PreparedDecoderBlock {
                ln1_g: blk.ln1_g.clone(),
                ln1_b: blk.ln1_b.clone(),
                sq: kernel_weight(&blk.sq, d, d, quant, per_channel),
                sk: kernel_weight(&blk.sk, d, d, quant, per_channel),
                sv: kernel_weight(&blk.sv, d, d, quant, per_channel),
                so: kernel_weight(&blk.so, d, d, quant, per_channel),
                lnx_g: blk.lnx_g.clone(),
                lnx_b: blk.lnx_b.clone(),
                xq: kernel_weight(&blk.xq, d, d, quant, per_channel),
                xk: kernel_weight(&blk.xk, d, d, quant, per_channel),
                xv: kernel_weight(&blk.xv, d, d, quant, per_channel),
                xo: kernel_weight(&blk.xo, d, d, quant, per_channel),
                ln2_g: blk.ln2_g.clone(),
                ln2_b: blk.ln2_b.clone(),
                w1: masked_kernel_weight(&blk.w1, d, f, tile, &mask1, quant, per_channel),
                b1: blk.b1.clone(),
                w2: masked_kernel_weight(&blk.w2, f, d, tile, &mask2, quant, per_channel),
                b2: blk.b2.clone(),
                mask1,
                mask2,
            });
        }
        Ok(PreparedDecoder {
            dims,
            tile,
            quant,
            emb: soft_weight(&w.emb, dims.vocab, d, quant, per_channel),
            blocks,
            lnf_g: w.lnf_g.clone(),
            lnf_b: w.lnf_b.clone(),
            head_w: soft_weight(&w.head_w, d, dims.vocab, quant, per_channel),
            head_b: w.head_b.clone(),
            pe: ops::sinusoidal_pe(dims.max_len, d),
            per_channel,
        })
    }

    /// Mean feed-forward tile sparsity of the staged masks.
    pub fn ff_sparsity(&self) -> f64 {
        let mut dead = 0usize;
        let mut total = 0usize;
        for blk in &self.blocks {
            dead += blk.mask1.n_tiles() - blk.mask1.live_count();
            dead += blk.mask2.n_tiles() - blk.mask2.live_count();
            total += blk.mask1.n_tiles() + blk.mask2.n_tiles();
        }
        dead as f64 / total.max(1) as f64
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::data::Tensor;
    use crate::pruning::norms::apply_mask_to_weights;
    use crate::util::rng::Rng;

    /// A small decoder that keeps debug-mode tests fast (pairs with
    /// `infer::testutil::mini_dims` made token-input).
    pub fn mini_dec_dims() -> DecoderDims {
        DecoderDims {
            vocab: 12,
            d_model: 32,
            n_heads: 4,
            d_ff: 64,
            n_blocks: 2,
            max_len: 10,
            tile: 8,
            bos: 1,
            eos: 2,
        }
    }

    pub fn random_dec_masks(
        dims: &DecoderDims,
        tile: usize,
        p_dead: f64,
        seed: u64,
    ) -> Vec<TileMask> {
        let mut rng = Rng::new(seed);
        let (kt, nt) = (dims.d_model / tile, dims.d_ff / tile);
        let mut out = Vec::new();
        for _ in 0..dims.n_blocks {
            out.push(TileMask {
                kt,
                nt,
                live: (0..kt * nt).map(|_| !rng.chance(p_dead)).collect(),
            });
            out.push(TileMask {
                kt: nt,
                nt: kt,
                live: (0..kt * nt).map(|_| !rng.chance(p_dead)).collect(),
            });
        }
        out
    }

    /// Zero the decoder feed-forward tiles the masks mark dead, in
    /// place — the prune-by-zeroing reference.
    pub fn zero_dec_ff_tiles(w: &mut DecoderWeights, masks: &[TileMask], tile: usize) {
        let (d, f) = (w.dims.d_model, w.dims.d_ff);
        for (i, blk) in w.blocks.iter_mut().enumerate() {
            let mut t1 = Tensor::from_f32(&[d, f], &blk.w1);
            apply_mask_to_weights(&mut t1, &masks[2 * i], tile);
            blk.w1 = t1.f32s();
            let mut t2 = Tensor::from_f32(&[f, d], &blk.w2);
            apply_mask_to_weights(&mut t2, &masks[2 * i + 1], tile);
            blk.w2 = t2.f32s();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::{mini_dec_dims, random_dec_masks};
    use super::*;
    use crate::infer::synth::synth_decoder_weights;

    #[test]
    fn bundle_roundtrip_preserves_weights() {
        let dims = mini_dec_dims();
        let w = synth_decoder_weights(&dims, 5);
        let mut b = Bundle::default();
        w.append_to_bundle(&mut b);
        let back = DecoderWeights::from_bundle(dims, &b).unwrap();
        assert_eq!(w.emb, back.emb);
        assert_eq!(w.blocks[1].xk, back.blocks[1].xk);
        assert_eq!(w.blocks[0].w2, back.blocks[0].w2);
        assert_eq!(w.head_b, back.head_b);
    }

    #[test]
    fn from_bundle_rejects_wrong_shapes() {
        let dims = mini_dec_dims();
        let w = synth_decoder_weights(&dims, 5);
        let mut b = Bundle::default();
        w.append_to_bundle(&mut b);
        b.insert("dec.head.w", Tensor::from_f32(&[2, 2], &[0.0; 4]));
        assert!(DecoderWeights::from_bundle(dims, &b).is_err());
    }

    #[test]
    fn ff_names_cover_recoverable_masks() {
        let dims = mini_dec_dims();
        let names = DecoderWeights::ff_names(dims.n_blocks);
        assert_eq!(names.len(), 2 * dims.n_blocks);
        assert_eq!(names[0], "dec.block0.ff.w1");
        assert_eq!(names[3], "dec.block1.ff.w2");
        // Zeroed tiles recover as dead masks.
        let mut w = synth_decoder_weights(&dims, 7);
        let masks = random_dec_masks(&dims, dims.tile, 0.4, 3);
        testutil::zero_dec_ff_tiles(&mut w, &masks, dims.tile);
        assert_eq!(w.recover_masks(dims.tile).unwrap(), masks);
    }

    #[test]
    fn prepared_decoder_rejects_bad_configs() {
        let dims = mini_dec_dims();
        let w = synth_decoder_weights(&dims, 9);
        assert!(PreparedDecoder::new(&w, 5, Quant::Fp32, None).is_err());
        let short = vec![TileMask::full(4, 8)];
        assert!(PreparedDecoder::new(&w, dims.tile, Quant::Fp32, Some(&short)).is_err());
        let bad = vec![TileMask::full(1, 1); 2 * dims.n_blocks];
        assert!(PreparedDecoder::new(&w, dims.tile, Quant::Fp32, Some(&bad)).is_err());
        let mut oov = w.clone();
        oov.dims.eos = oov.dims.vocab as i32;
        assert!(PreparedDecoder::new(&oov, dims.tile, Quant::Fp32, None).is_err());
        let ok = PreparedDecoder::new(&w, dims.tile, Quant::Fp32, None).unwrap();
        assert_eq!(ok.ff_sparsity(), 0.0);
        assert_eq!(ok.pe.len(), dims.max_len * dims.d_model);
    }
}
