//! [`DecoderForward`] — the incremental KV-cache decode runtime plus the
//! full-prefix recompute reference.
//!
//! Each generated token costs **one step**: six `[1, d]` GEMV
//! projections plus the masked feed-forward pair per block, attention
//! over the cached K/V prefix, and the vocabulary head. The caches make
//! the step *bitwise identical* to re-running the whole prefix through
//! the stack ([`DecoderForward::full_prefix`]): every kernel in
//! [`super::super::gemm`] computes output rows independently with
//! k-ascending accumulation, so a K/V row produced by the `m = 1` GEMV
//! at its own step is the same f32 sequence the `m = len` recompute
//! produces for that row, and causal attention is realized by iterating
//! only the `0..=pos` prefix (no additive mask), which keeps the
//! arithmetic of both paths literally identical. The identity is
//! property-tested on both weight formats below.
//!
//! Cross-attention K/V are computed **once per utterance**
//! (`m = src_len` GEMMs at [`DecoderForward::start`], accounted in
//! [`DecodeStats::cross_kv`]) and reused every step — the decode-side
//! weight-stationary reuse. The per-step GEMVs are accounted with
//! [`crate::systolic::TileTiming`] at `m = 1`, matching
//! [`crate::sysim::engine::gemm_on_array_decode`] exactly (asserted in
//! the tests below).

use crate::systolic::Quant;
use crate::telemetry;

use super::super::gemm::{gemm_f32, TileStats};
use super::super::layers::{self, Layer};
use super::super::ops;
use super::PreparedDecoder;

/// Per-run decode statistics, split by GEMM role.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DecodeStats {
    /// Feed-forward GEMVs (the SASP-pruned pair, one `m = 1` pass per
    /// block per step).
    pub ff: TileStats,
    /// Self/cross attention projections (`sq sk sv so xq xo`, `m = 1`
    /// per block per step).
    pub attn: TileStats,
    /// Cross-attention K/V precompute — once per utterance, reused
    /// every step. Per-utterance paths ([`DecoderForward::start`],
    /// [`DecoderForward::full_prefix`]) charge `m = src_len`; the
    /// batched translate path streams the full padded
    /// `[batch * seq_len]` memory panel weight-stationary (the
    /// rectangular batched schedule, like the batched encoder), so it
    /// charges `m = seq_len` per utterance with programming amortized
    /// across the batch.
    pub cross_kv: TileStats,
    /// Vocabulary head (software-executed).
    pub other: TileStats,
    /// Decode steps executed since the last reset.
    pub steps: usize,
    /// Utterances started since the last reset.
    pub utterances: usize,
}

impl DecodeStats {
    /// Merge another run's counters (the continuous scheduler keeps its
    /// own [`DecodeStats`] and folds them into the backend's canonical
    /// accumulator when a session ends).
    pub fn add(&mut self, o: &DecodeStats) {
        self.ff.add(&o.ff);
        self.attn.add(&o.attn);
        self.cross_kv.add(&o.cross_kv);
        self.other.add(&o.other);
        self.steps += o.steps;
        self.utterances += o.utterances;
    }

    /// Sum of all GEMM-scope counters (ff + attn + cross-K/V + head) —
    /// the aggregate telemetry spans attach to one decode step.
    pub fn total(&self) -> TileStats {
        let mut t = self.ff;
        t.add(&self.attn);
        t.add(&self.cross_kv);
        t.add(&self.other);
        t
    }
}

/// One query row attending over `n_keys` K/V rows (multi-head, no
/// masking — callers pass the causal prefix or the valid source
/// prefix). The **only** attention arithmetic in this module *and* in
/// the continuous scheduler ([`super::continuous`]): the KV-cache step,
/// the full-prefix recompute, and every continuous panel slot all run
/// through here, which is what makes their agreement bitwise.
pub(crate) fn attend_row(
    q: &[f32],
    keys: &[f32],
    vals: &[f32],
    n_keys: usize,
    d: usize,
    n_heads: usize,
    scores: &mut Vec<f32>,
    ctx: &mut [f32],
) {
    debug_assert!(n_keys > 0 && keys.len() >= n_keys * d && vals.len() >= n_keys * d);
    let hd = d / n_heads;
    let inv_sqrt_hd = 1.0 / (hd as f32).sqrt();
    scores.clear();
    scores.resize(n_keys, 0.0);
    for head in 0..n_heads {
        let c0 = head * hd;
        for b in 0..n_keys {
            let mut acc = 0.0f32;
            for j in 0..hd {
                acc += q[c0 + j] * keys[b * d + c0 + j];
            }
            scores[b] = acc * inv_sqrt_hd;
        }
        ops::softmax_rows(scores, n_keys);
        for j in 0..hd {
            let mut acc = 0.0f32;
            for b in 0..n_keys {
                acc += scores[b] * vals[b * d + c0 + j];
            }
            ctx[c0 + j] = acc;
        }
    }
}

/// The decode runtime: owns the per-block KV caches and every
/// intermediate buffer, so steady-state generation performs no
/// allocation beyond growth to the longest sequence seen.
pub struct DecoderForward {
    /// Per-block causal self-attention caches (`pos x d`, grown one row
    /// per step).
    self_k: Vec<Vec<f32>>,
    self_v: Vec<Vec<f32>>,
    /// Per-block cross-attention K/V (`src_len x d`, fixed per
    /// utterance).
    cross_k: Vec<Vec<f32>>,
    cross_v: Vec<Vec<f32>>,
    src_len: usize,
    pos: usize,
    h: Vec<f32>,
    hn: Vec<f32>,
    q: Vec<f32>,
    ctx: Vec<f32>,
    tmp: Vec<f32>,
    mid: Vec<f32>,
    scores: Vec<f32>,
    kv_row: Vec<f32>,
    k_buf: Vec<f32>,
    v_buf: Vec<f32>,
    logits_buf: Vec<f32>,
    pub stats: DecodeStats,
}

impl Default for DecoderForward {
    fn default() -> Self {
        DecoderForward::new()
    }
}

impl DecoderForward {
    pub fn new() -> Self {
        DecoderForward {
            self_k: Vec::new(),
            self_v: Vec::new(),
            cross_k: Vec::new(),
            cross_v: Vec::new(),
            src_len: 0,
            pos: 0,
            h: Vec::new(),
            hn: Vec::new(),
            q: Vec::new(),
            ctx: Vec::new(),
            tmp: Vec::new(),
            mid: Vec::new(),
            scores: Vec::new(),
            kv_row: Vec::new(),
            k_buf: Vec::new(),
            v_buf: Vec::new(),
            logits_buf: Vec::new(),
            stats: DecodeStats::default(),
        }
    }

    /// Number of steps taken for the current utterance (== the position
    /// the next token will occupy).
    pub fn pos(&self) -> usize {
        self.pos
    }

    fn reset_caches(&mut self, n_blocks: usize) {
        self.self_k.resize_with(n_blocks, Vec::new);
        self.self_v.resize_with(n_blocks, Vec::new);
        self.cross_k.resize_with(n_blocks, Vec::new);
        self.cross_v.resize_with(n_blocks, Vec::new);
        for c in self
            .self_k
            .iter_mut()
            .chain(self.self_v.iter_mut())
            .chain(self.cross_k.iter_mut())
            .chain(self.cross_v.iter_mut())
        {
            c.clear();
        }
        self.pos = 0;
    }

    /// Begin one utterance: reset the self-attention caches and compute
    /// the cross-attention K/V from the encoder memory (`src_len x
    /// d_model`, the post-final-LayerNorm hidden states) — once, reused
    /// by every subsequent [`Self::step`].
    pub fn start(&mut self, m: &PreparedDecoder, memory: &[f32], src_len: usize) {
        let d = m.dims.d_model;
        assert!(src_len > 0, "empty source");
        assert_eq!(memory.len(), src_len * d, "memory must be src_len x d");
        self.reset_caches(m.blocks.len());
        self.src_len = src_len;
        let mut span = telemetry::Span::begin("decode.cross_kv");
        let before = if span.is_live() { self.stats.cross_kv } else { TileStats::default() };
        for (i, blk) in m.blocks.iter().enumerate() {
            let stk = blk.xk.gemm(memory, src_len, None, m.tile, &mut self.cross_k[i]);
            let stv = blk.xv.gemm(memory, src_len, None, m.tile, &mut self.cross_v[i]);
            self.stats.cross_kv.add(&stk);
            self.stats.cross_kv.add(&stv);
            layers::record(Layer::CrossKv, &stk, m.tile, m.quant);
            layers::record(Layer::CrossKv, &stv, m.tile, m.quant);
        }
        if span.is_live() {
            span.attr("src_len", src_len);
            self.stats.cross_kv.minus(&before).annotate(&mut span);
        }
        self.stats.utterances += 1;
    }

    /// Begin one utterance with **externally precomputed** cross K/V
    /// (the batched serving path, where the per-block K/V GEMMs run
    /// weight-stationary across the whole batch): `kv(i)` returns the
    /// block-`i` `(K, V)` slices, each `src_len x d_model`. The caller
    /// owns the accounting of the batched precompute.
    pub fn start_with<'a>(
        &mut self,
        m: &PreparedDecoder,
        src_len: usize,
        kv: impl Fn(usize) -> (&'a [f32], &'a [f32]),
    ) {
        let d = m.dims.d_model;
        assert!(src_len > 0, "empty source");
        self.reset_caches(m.blocks.len());
        self.src_len = src_len;
        for i in 0..m.blocks.len() {
            let (k, v) = kv(i);
            assert_eq!(k.len(), src_len * d, "block {i} cross-K shape");
            assert_eq!(v.len(), src_len * d, "block {i} cross-V shape");
            self.cross_k[i].extend_from_slice(k);
            self.cross_v[i].extend_from_slice(v);
        }
        self.stats.utterances += 1;
    }

    /// One incremental decode step: feed the token occupying position
    /// [`Self::pos`] and produce the next-token logits (`vocab`,
    /// unnormalized) in `logits`.
    pub fn step(&mut self, m: &PreparedDecoder, token: i32, logits: &mut Vec<f32>) {
        let mut span = telemetry::Span::begin("decode.step");
        let before = if span.is_live() { self.stats.total() } else { TileStats::default() };
        let dims = &m.dims;
        let (d, v) = (dims.d_model, dims.vocab);
        let p = self.pos;
        assert!(p < dims.max_len, "decode step past max_len {}", dims.max_len);
        assert!(self.src_len > 0, "step before start()");
        let ti = token as usize;
        assert!(ti < v, "token {ti} out of vocab {v}");
        self.h.clear();
        self.h.extend_from_slice(&m.emb[ti * d..(ti + 1) * d]);
        ops::residual_add(&mut self.h, &m.pe[p * d..(p + 1) * d]);
        self.ctx.clear();
        self.ctx.resize(d, 0.0);

        for (i, blk) in m.blocks.iter().enumerate() {
            // --- causal masked self-attention over the cached prefix --
            self.hn.clear();
            self.hn.extend_from_slice(&self.h);
            ops::layer_norm(&mut self.hn, d, &blk.ln1_g, &blk.ln1_b);
            let sq = blk.sq.gemm(&self.hn, 1, None, m.tile, &mut self.q);
            let sk = blk.sk.gemm(&self.hn, 1, None, m.tile, &mut self.kv_row);
            self.self_k[i].extend_from_slice(&self.kv_row);
            let sv = blk.sv.gemm(&self.hn, 1, None, m.tile, &mut self.kv_row);
            self.self_v[i].extend_from_slice(&self.kv_row);
            self.stats.attn.add(&sq);
            self.stats.attn.add(&sk);
            self.stats.attn.add(&sv);
            layers::record(Layer::DecAttn, &sq, m.tile, m.quant);
            layers::record(Layer::DecAttn, &sk, m.tile, m.quant);
            layers::record(Layer::DecAttn, &sv, m.tile, m.quant);
            attend_row(
                &self.q,
                &self.self_k[i],
                &self.self_v[i],
                p + 1,
                d,
                dims.n_heads,
                &mut self.scores,
                &mut self.ctx,
            );
            let so = blk.so.gemm(&self.ctx, 1, None, m.tile, &mut self.tmp);
            self.stats.attn.add(&so);
            layers::record(Layer::DecAttn, &so, m.tile, m.quant);
            ops::residual_add(&mut self.h, &self.tmp);

            // --- encoder-decoder cross-attention (K/V reused) ---------
            self.hn.clear();
            self.hn.extend_from_slice(&self.h);
            ops::layer_norm(&mut self.hn, d, &blk.lnx_g, &blk.lnx_b);
            let xq = blk.xq.gemm(&self.hn, 1, None, m.tile, &mut self.q);
            self.stats.attn.add(&xq);
            layers::record(Layer::DecAttn, &xq, m.tile, m.quant);
            attend_row(
                &self.q,
                &self.cross_k[i],
                &self.cross_v[i],
                self.src_len,
                d,
                dims.n_heads,
                &mut self.scores,
                &mut self.ctx,
            );
            let xo = blk.xo.gemm(&self.ctx, 1, None, m.tile, &mut self.tmp);
            self.stats.attn.add(&xo);
            layers::record(Layer::DecAttn, &xo, m.tile, m.quant);
            ops::residual_add(&mut self.h, &self.tmp);

            // --- pre-LN SASP feed-forward -----------------------------
            self.hn.clear();
            self.hn.extend_from_slice(&self.h);
            ops::layer_norm(&mut self.hn, d, &blk.ln2_g, &blk.ln2_b);
            let mut ff_span = telemetry::Span::begin("gemm.decode_ff");
            let s1 = blk.w1.gemm(&self.hn, 1, Some(&blk.mask1), m.tile, &mut self.mid);
            self.stats.ff.add(&s1);
            layers::record(Layer::DecFf, &s1, m.tile, m.quant);
            ops::add_bias(&mut self.mid, &blk.b1);
            ops::relu(&mut self.mid);
            let s2 = blk.w2.gemm(&self.mid, 1, Some(&blk.mask2), m.tile, &mut self.tmp);
            self.stats.ff.add(&s2);
            layers::record(Layer::DecFf, &s2, m.tile, m.quant);
            if ff_span.is_live() {
                // The SASP-pruned GEMV pair, with its masked-tile
                // accounting (the per-GEMM sparsity evidence).
                ff_span.attr("block", i);
                let mut ff = s1;
                ff.add(&s2);
                ff.annotate(&mut ff_span);
            }
            drop(ff_span);
            ops::add_bias(&mut self.tmp, &blk.b2);
            ops::residual_add(&mut self.h, &self.tmp);
        }

        self.hn.clear();
        self.hn.extend_from_slice(&self.h);
        ops::layer_norm(&mut self.hn, d, &m.lnf_g, &m.lnf_b);
        let st = gemm_f32(&self.hn, &m.head_w, 1, d, v, None, m.tile, logits);
        self.stats.other.add(&st);
        layers::record(Layer::Head, &st, m.tile, Quant::Fp32);
        ops::add_bias(logits, &m.head_b);
        self.pos += 1;
        self.stats.steps += 1;
        if span.is_live() {
            span.attr("pos", p);
            self.stats.total().minus(&before).annotate(&mut span);
        }
    }

    /// Greedy autoregressive generation over a started utterance:
    /// BOS-seeded, stops at EOS or `max_len` steps. `out` receives the
    /// generated tokens (BOS/EOS excluded).
    pub fn generate_started(&mut self, m: &PreparedDecoder, out: &mut Vec<i32>) {
        assert_eq!(self.pos, 0, "generate_started on a mid-stream decoder");
        out.clear();
        let mut logits = std::mem::take(&mut self.logits_buf);
        let mut tok = m.dims.bos;
        for _ in 0..m.dims.max_len {
            self.step(m, tok, &mut logits);
            let mut best = 0usize;
            for (i, l) in logits.iter().enumerate() {
                if *l > logits[best] {
                    best = i;
                }
            }
            let next = best as i32;
            if next == m.dims.eos {
                break;
            }
            out.push(next);
            tok = next;
        }
        self.logits_buf = logits;
    }

    /// Greedy generation for one utterance: [`Self::start`] +
    /// [`Self::generate_started`].
    pub fn generate(
        &mut self,
        m: &PreparedDecoder,
        memory: &[f32],
        src_len: usize,
        out: &mut Vec<i32>,
    ) {
        self.start(m, memory, src_len);
        self.generate_started(m, out);
    }

    /// Full-prefix recompute reference: run the whole token prefix
    /// through the decoder stack with no cache, producing next-token
    /// logits for **every** position (`len x vocab` in `logits`). Row
    /// `p` is bitwise identical to what [`Self::step`] produces at
    /// position `p` — the KV-cache exactness contract.
    pub fn full_prefix(
        &mut self,
        m: &PreparedDecoder,
        memory: &[f32],
        src_len: usize,
        tokens: &[i32],
        logits: &mut Vec<f32>,
    ) {
        let dims = &m.dims;
        let (d, v) = (dims.d_model, dims.vocab);
        let len = tokens.len();
        assert!(len > 0 && len <= dims.max_len, "prefix length {len} out of range");
        assert!(src_len > 0, "empty source");
        assert_eq!(memory.len(), src_len * d, "memory must be src_len x d");
        self.h.clear();
        self.h.resize(len * d, 0.0);
        for (row, tok) in tokens.iter().enumerate() {
            let ti = *tok as usize;
            assert!(ti < v, "token {ti} out of vocab {v}");
            self.h[row * d..(row + 1) * d].copy_from_slice(&m.emb[ti * d..(ti + 1) * d]);
            ops::residual_add(
                &mut self.h[row * d..(row + 1) * d],
                &m.pe[row * d..(row + 1) * d],
            );
        }
        self.ctx.clear();
        self.ctx.resize(len * d, 0.0);

        for blk in &m.blocks {
            // --- causal self-attention (recomputed, no cache) ---------
            self.hn.clear();
            self.hn.extend_from_slice(&self.h);
            ops::layer_norm(&mut self.hn, d, &blk.ln1_g, &blk.ln1_b);
            let sq = blk.sq.gemm(&self.hn, len, None, m.tile, &mut self.q);
            let sk = blk.sk.gemm(&self.hn, len, None, m.tile, &mut self.k_buf);
            let sv = blk.sv.gemm(&self.hn, len, None, m.tile, &mut self.v_buf);
            self.stats.attn.add(&sq);
            self.stats.attn.add(&sk);
            self.stats.attn.add(&sv);
            for a in 0..len {
                attend_row(
                    &self.q[a * d..(a + 1) * d],
                    &self.k_buf,
                    &self.v_buf,
                    a + 1,
                    d,
                    dims.n_heads,
                    &mut self.scores,
                    &mut self.ctx[a * d..(a + 1) * d],
                );
            }
            let so = blk.so.gemm(&self.ctx, len, None, m.tile, &mut self.tmp);
            self.stats.attn.add(&so);
            ops::residual_add(&mut self.h, &self.tmp);

            // --- cross-attention (K/V recomputed per call) ------------
            self.hn.clear();
            self.hn.extend_from_slice(&self.h);
            ops::layer_norm(&mut self.hn, d, &blk.lnx_g, &blk.lnx_b);
            let xq = blk.xq.gemm(&self.hn, len, None, m.tile, &mut self.q);
            let xk = blk.xk.gemm(memory, src_len, None, m.tile, &mut self.k_buf);
            let xv = blk.xv.gemm(memory, src_len, None, m.tile, &mut self.v_buf);
            self.stats.attn.add(&xq);
            self.stats.cross_kv.add(&xk);
            self.stats.cross_kv.add(&xv);
            for a in 0..len {
                attend_row(
                    &self.q[a * d..(a + 1) * d],
                    &self.k_buf,
                    &self.v_buf,
                    src_len,
                    d,
                    dims.n_heads,
                    &mut self.scores,
                    &mut self.ctx[a * d..(a + 1) * d],
                );
            }
            let xo = blk.xo.gemm(&self.ctx, len, None, m.tile, &mut self.tmp);
            self.stats.attn.add(&xo);
            ops::residual_add(&mut self.h, &self.tmp);

            // --- pre-LN SASP feed-forward -----------------------------
            self.hn.clear();
            self.hn.extend_from_slice(&self.h);
            ops::layer_norm(&mut self.hn, d, &blk.ln2_g, &blk.ln2_b);
            let s1 = blk.w1.gemm(&self.hn, len, Some(&blk.mask1), m.tile, &mut self.mid);
            self.stats.ff.add(&s1);
            ops::add_bias(&mut self.mid, &blk.b1);
            ops::relu(&mut self.mid);
            let s2 = blk.w2.gemm(&self.mid, len, Some(&blk.mask2), m.tile, &mut self.tmp);
            self.stats.ff.add(&s2);
            ops::add_bias(&mut self.tmp, &blk.b2);
            ops::residual_add(&mut self.h, &self.tmp);
        }

        self.hn.clear();
        self.hn.extend_from_slice(&self.h);
        ops::layer_norm(&mut self.hn, d, &m.lnf_g, &m.lnf_b);
        let st = gemm_f32(&self.hn, &m.head_w, len, d, v, None, m.tile, logits);
        self.stats.other.add(&st);
        ops::add_bias(logits, &m.head_b);
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{mini_dec_dims, random_dec_masks, zero_dec_ff_tiles};
    use super::super::{DecoderDims, DecoderWeights, PreparedDecoder};
    use super::*;
    use crate::data::Tensor;
    use crate::infer::synth::synth_decoder_weights;
    use crate::quant::{fake_quantize, fake_quantize_per_channel};
    use crate::systolic::Quant;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn random_memory(rng: &mut Rng, src_len: usize, d: usize) -> Vec<f32> {
        (0..src_len * d).map(|_| rng.normal() as f32 * 0.5).collect()
    }

    fn random_tokens(rng: &mut Rng, len: usize, vocab: usize) -> Vec<i32> {
        (0..len).map(|_| rng.index(vocab) as i32).collect()
    }

    #[test]
    fn prop_kv_cache_step_bitwise_equals_full_prefix() {
        // The tentpole contract on both weight formats: stepping with
        // the KV cache produces, at every position, exactly the logits
        // the cache-free full-prefix recompute produces — bitwise.
        check("kv-cache step == full-prefix recompute", 12, |rng: &mut Rng| {
            let dims = mini_dec_dims();
            let quant = if rng.chance(0.5) { Quant::Fp32 } else { Quant::Int8 };
            let w = synth_decoder_weights(&dims, rng.next_u64());
            let masks = random_dec_masks(&dims, dims.tile, 0.35, rng.next_u64());
            let m = PreparedDecoder::new(&w, dims.tile, quant, Some(&masks)).unwrap();
            let src_len = rng.index(12) + 2;
            let memory = random_memory(rng, src_len, dims.d_model);
            let len = rng.index(dims.max_len - 1) + 1;
            let tokens = random_tokens(rng, len, dims.vocab);

            let mut fwd = DecoderForward::new();
            let mut stepped = Vec::new();
            let mut row = Vec::new();
            fwd.start(&m, &memory, src_len);
            for &t in &tokens {
                fwd.step(&m, t, &mut row);
                stepped.extend_from_slice(&row);
            }
            let mut full = Vec::new();
            fwd.full_prefix(&m, &memory, src_len, &tokens, &mut full);
            if stepped != full {
                return (false, format!("{quant:?} len={len} src={src_len}"));
            }
            // Causality: a shorter prefix reproduces the same rows.
            let cut = len.div_ceil(2);
            let mut part = Vec::new();
            fwd.full_prefix(&m, &memory, src_len, &tokens[..cut], &mut part);
            (
                part == full[..cut * dims.vocab],
                format!("{quant:?} causality at cut={cut}"),
            )
        });
    }

    #[test]
    fn tile_skipping_equals_zeroed_weights() {
        // SASP identity at decoder scope: skipping ff tiles == running
        // dense over weights with those tiles zeroed.
        let dims = mini_dec_dims();
        let w = synth_decoder_weights(&dims, 7);
        let masks = random_dec_masks(&dims, dims.tile, 0.4, 3);
        let masked = PreparedDecoder::new(&w, dims.tile, Quant::Fp32, Some(&masks)).unwrap();
        let mut wz = w.clone();
        zero_dec_ff_tiles(&mut wz, &masks, dims.tile);
        let zeroed = PreparedDecoder::new(&wz, dims.tile, Quant::Fp32, None).unwrap();

        let mut rng = Rng::new(5);
        let memory = random_memory(&mut rng, 9, dims.d_model);
        let tokens = random_tokens(&mut rng, 6, dims.vocab);
        let mut fwd = DecoderForward::new();
        let mut a = Vec::new();
        fwd.full_prefix(&masked, &memory, 9, &tokens, &mut a);
        let skipped = fwd.stats.ff.tiles_skipped;
        let mut b = Vec::new();
        fwd.full_prefix(&zeroed, &memory, 9, &tokens, &mut b);
        assert!(skipped > 0, "mask must actually skip tiles");
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() <= 1e-5, "{x} vs {y}");
        }
    }

    /// Fake-quantize every 2-D decoder matrix in place with `fq`.
    fn fq_all(w: &mut DecoderWeights, fq: impl Fn(&mut Vec<f32>, usize, usize)) {
        let (d, f, v) = (w.dims.d_model, w.dims.d_ff, w.dims.vocab);
        fq(&mut w.emb, v, d);
        fq(&mut w.head_w, d, v);
        for blk in w.blocks.iter_mut() {
            for m in [
                &mut blk.sq, &mut blk.sk, &mut blk.sv, &mut blk.so,
                &mut blk.xq, &mut blk.xk, &mut blk.xv, &mut blk.xo,
            ] {
                fq(m, d, d);
            }
            fq(&mut blk.w1, d, f);
            fq(&mut blk.w2, f, d);
        }
    }

    fn assert_int8_matches_fq_fp32(per_channel: bool, seed: u64) {
        let dims = mini_dec_dims();
        let w = synth_decoder_weights(&dims, seed);
        let masks = random_dec_masks(&dims, dims.tile, 0.3, seed ^ 1);
        let int8 =
            PreparedDecoder::new_with(&w, dims.tile, Quant::Int8, Some(&masks), per_channel)
                .unwrap();
        assert_eq!(int8.per_channel, per_channel);
        let mut wfq = w.clone();
        zero_dec_ff_tiles(&mut wfq, &masks, dims.tile);
        fq_all(&mut wfq, |vals, r, c| {
            let mut t = Tensor::from_f32(&[r, c], vals);
            if per_channel {
                fake_quantize_per_channel(&mut t);
            } else {
                fake_quantize(&mut t);
            }
            *vals = t.f32s();
        });
        let fp32 = PreparedDecoder::new(&wfq, dims.tile, Quant::Fp32, Some(&masks)).unwrap();

        let mut rng = Rng::new(seed ^ 2);
        let src_len = 7usize;
        let memory = random_memory(&mut rng, src_len, dims.d_model);
        let mut fwd = DecoderForward::new();
        let mut a = Vec::new();
        let mut b = Vec::new();
        fwd.generate(&int8, &memory, src_len, &mut a);
        let mut toks_a = Vec::new();
        let mut row = Vec::new();
        fwd.start(&int8, &memory, src_len);
        fwd.step(&int8, dims.bos, &mut row);
        toks_a.extend_from_slice(&row);
        fwd.generate(&fp32, &memory, src_len, &mut b);
        let mut toks_b = Vec::new();
        fwd.start(&fp32, &memory, src_len);
        fwd.step(&fp32, dims.bos, &mut row);
        toks_b.extend_from_slice(&row);
        // Kernel INT8 == FP32 over fake-quantized weights, exactly:
        // identical first-step logits and identical greedy outputs.
        assert_eq!(toks_a, toks_b, "pc={per_channel}: first-step logits");
        assert_eq!(a, b, "pc={per_channel}: greedy decode");
    }

    #[test]
    fn int8_decode_matches_fake_quantized_fp32() {
        assert_int8_matches_fq_fp32(false, 11);
    }

    #[test]
    fn per_channel_int8_decode_matches_fake_quantized_fp32() {
        // Satellite: per-channel LUTs flow through the decoder staging
        // path with the same oracle identity as the encoder.
        assert_int8_matches_fq_fp32(true, 13);
    }

    #[test]
    fn functional_decode_stats_match_analytic_accounting() {
        // Decode-scope functional x analytic cross-check: the per-step
        // [1, d] GEMVs must cost exactly what the analytic decode-step
        // scheduler charges, and the cross-attention K/V precompute must
        // cost exactly one m = src_len pass per projection — reused (not
        // recharged) across steps.
        use crate::model::{GemmKind, GemmShape};
        use crate::sysim::engine::{gemm_on_array, gemm_on_array_decode};
        use crate::sysim::SimParams;
        use crate::systolic::ArrayConfig;

        let dims = mini_dec_dims();
        let w = synth_decoder_weights(&dims, 17);
        let masks = random_dec_masks(&dims, dims.tile, 0.5, 19);
        let m = PreparedDecoder::new(&w, dims.tile, Quant::Int8, Some(&masks)).unwrap();
        let mut rng = Rng::new(23);
        let src_len = 11usize;
        let memory = random_memory(&mut rng, src_len, dims.d_model);
        let mut fwd = DecoderForward::new();
        let mut out = Vec::new();
        fwd.generate(&m, &memory, src_len, &mut out);
        let steps = fwd.stats.steps;
        assert!(steps > 0);

        let cfg = ArrayConfig::square(dims.tile, Quant::Int8);
        let p = SimParams::default();
        let (d, f) = (dims.d_model, dims.d_ff);
        let proj = GemmShape { m: 1, k: d, n: d, kind: GemmKind::AttnProj };
        let mut ff_macs = 0u64;
        let mut ff_words = 0u64;
        let mut ff_cycles = 0u64;
        let mut attn_macs = 0u64;
        let mut attn_words = 0u64;
        let mut attn_cycles = 0u64;
        let mut kv_macs = 0u64;
        let mut kv_words = 0u64;
        let mut kv_cycles = 0u64;
        for i in 0..dims.n_blocks {
            let g1 = GemmShape { m: 1, k: d, n: f, kind: GemmKind::FeedForward };
            let g2 = GemmShape { m: 1, k: f, n: d, kind: GemmKind::FeedForward };
            let c1 = gemm_on_array_decode(&g1, &cfg, &p, Some(&masks[2 * i]), steps);
            let c2 = gemm_on_array_decode(&g2, &cfg, &p, Some(&masks[2 * i + 1]), steps);
            ff_macs += c1.counts.macs + c2.counts.macs;
            ff_words += c1.counts.bus_words + c2.counts.bus_words;
            ff_cycles += c1.counts.array_busy_cycles + c2.counts.array_busy_cycles;
            // sq sk sv so xq xo: six per-step projections.
            let cp = gemm_on_array_decode(&proj, &cfg, &p, None, steps);
            attn_macs += 6 * cp.counts.macs;
            attn_words += 6 * cp.counts.bus_words;
            attn_cycles += 6 * cp.counts.array_busy_cycles;
            // Cross K/V: one m = src_len pass each, per utterance.
            let gkv = GemmShape { m: src_len, k: d, n: d, kind: GemmKind::AttnProj };
            let ckv = gemm_on_array(&gkv, &cfg, &p, None);
            kv_macs += 2 * ckv.counts.macs;
            kv_words += 2 * ckv.counts.bus_words;
            kv_cycles += 2 * ckv.counts.array_busy_cycles;
        }
        assert_eq!(fwd.stats.ff.timing.macs as u64, ff_macs);
        assert_eq!(fwd.stats.ff.timing.total_words() as u64, ff_words);
        assert_eq!(fwd.stats.ff.timing.array_cycles as u64, ff_cycles);
        assert_eq!(fwd.stats.attn.timing.macs as u64, attn_macs);
        assert_eq!(fwd.stats.attn.timing.total_words() as u64, attn_words);
        assert_eq!(fwd.stats.attn.timing.array_cycles as u64, attn_cycles);
        assert_eq!(fwd.stats.cross_kv.timing.macs as u64, kv_macs);
        assert_eq!(fwd.stats.cross_kv.timing.total_words() as u64, kv_words);
        assert_eq!(fwd.stats.cross_kv.timing.array_cycles as u64, kv_cycles);
        // The skip schedule: per step, each live ff tile once.
        let live: usize = masks.iter().map(crate::sysim::TileMask::live_count).sum();
        let dead: usize = masks.iter().map(|m| m.n_tiles() - m.live_count()).sum();
        assert_eq!(fwd.stats.ff.tiles_live, steps * live);
        assert_eq!(fwd.stats.ff.tiles_skipped, steps * dead);
    }

    #[test]
    fn start_with_precomputed_kv_matches_start() {
        let dims = mini_dec_dims();
        let w = synth_decoder_weights(&dims, 29);
        let m = PreparedDecoder::new(&w, dims.tile, Quant::Fp32, None).unwrap();
        let mut rng = Rng::new(31);
        let src_len = 6usize;
        let memory = random_memory(&mut rng, src_len, dims.d_model);
        let tokens = random_tokens(&mut rng, 5, dims.vocab);

        let mut fwd = DecoderForward::new();
        let mut a = Vec::new();
        let mut row = Vec::new();
        fwd.start(&m, &memory, src_len);
        for &t in &tokens {
            fwd.step(&m, t, &mut row);
            a.extend_from_slice(&row);
        }
        // Precompute the cross K/V externally with the same kernels.
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        for blk in &m.blocks {
            let mut k = Vec::new();
            let mut v = Vec::new();
            blk.xk.gemm(&memory, src_len, None, m.tile, &mut k);
            blk.xv.gemm(&memory, src_len, None, m.tile, &mut v);
            ks.push(k);
            vs.push(v);
        }
        let mut b = Vec::new();
        fwd.start_with(&m, src_len, |i| (ks[i].as_slice(), vs[i].as_slice()));
        for &t in &tokens {
            fwd.step(&m, t, &mut row);
            b.extend_from_slice(&row);
        }
        assert_eq!(a, b, "precomputed cross K/V must be transparent");
    }

    #[test]
    fn generation_stops_at_eos_and_max_len() {
        let dims = mini_dec_dims();
        let w = synth_decoder_weights(&dims, 37);
        let mut rng = Rng::new(41);
        let memory = random_memory(&mut rng, 5, dims.d_model);
        // Random tiny decoders rarely emit EOS: generation must cap at
        // max_len steps.
        let m = PreparedDecoder::new(&w, dims.tile, Quant::Fp32, None).unwrap();
        let mut fwd = DecoderForward::new();
        let mut out = Vec::new();
        fwd.generate(&m, &memory, 5, &mut out);
        assert!(out.len() <= dims.max_len);
        assert!(out.iter().all(|t| *t >= 0 && (*t as usize) < dims.vocab));
        assert!(out.iter().all(|t| *t != dims.eos));
        // A head biased hard toward EOS stops immediately: empty output.
        let mut weos = w.clone();
        weos.head_b[dims.eos as usize] = 1e6;
        let meos = PreparedDecoder::new(&weos, dims.tile, Quant::Fp32, None).unwrap();
        fwd.stats = DecodeStats::default();
        fwd.generate(&meos, &memory, 5, &mut out);
        assert!(out.is_empty(), "EOS-first decode must stop at once");
        assert_eq!(fwd.stats.steps, 1);
        assert_eq!(fwd.stats.utterances, 1);
    }

    #[test]
    fn decoder_dims_helpers() {
        let dims = DecoderDims::tiny_mt();
        assert_eq!(dims.head_dim(), 16);
        assert!(dims.tile_ok(8));
        assert!(!dims.tile_ok(7));
        assert!(!dims.tile_ok(0));
    }
}
