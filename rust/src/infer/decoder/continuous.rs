//! [`ContinuousDecoder`] — iteration-level (continuous) batched
//! decoding: the LLM-server-style step scheduler for MT.
//!
//! The sequential decode path ([`super::DecoderForward`]) issues one
//! skinny `[1, d]` GEMV per weight matrix per generated token — exactly
//! the shape that starves a wide weight-stationary array, because every
//! live tile is programmed for a single activation row. This scheduler
//! steps many in-flight decodes **in lockstep**: at each step the `k`
//! live slots' token rows are gathered into one `[k, d]` panel and every
//! weight GEMM runs on the batched weight-stationary kernels
//! ([`crate::infer::batch::gemm`]), so each live tile is programmed once
//! per step and streamed by all `k` slots ([`crate::systolic::
//! TileTiming::batched`] at `m = 1`). Slots join and leave **between
//! steps**: a slot that emits EOS or hits `max_len` retires at the end
//! of its step and the caller immediately refills the panel from its
//! admission queue, so the panel stays as full as the queue allows —
//! the batch composition is different every step, which is why the
//! analytic counterpart ([`crate::sysim::engine::
//! gemm_on_array_decode_batched`]) takes the whole per-step slot-count
//! schedule ([`ContinuousDecoder::step_batches`]).
//!
//! **Bitwise contract.** Each slot's generated tokens are bitwise
//! identical to running [`super::DecoderForward::generate`] on that
//! utterance alone, regardless of which slots share its panels:
//!
//! - every batched weight kernel streams rows through each packed tile
//!   with the same per-output-element k-ascending accumulation as the
//!   per-utterance kernels (property-proven row-wise bitwise equality
//!   in [`crate::infer::batch::gemm`]),
//! - attention runs per slot through [`super::forward::attend_row`] —
//!   the *only* attention arithmetic in the decoder — over that slot's
//!   own KV caches, and
//! - LayerNorm / bias / ReLU / residual are row-wise.
//!
//! So batch composition is invisible to the arithmetic; it only changes
//! the accounting (tile programming amortized across the live slots).
//! The contract is property-tested below under random join/leave
//! schedules on both weight formats.

use crate::systolic::Quant;
use crate::telemetry::{self, LazyHistogram};

use super::super::batch::gemm::gemm_batched_f32;
use super::super::gemm::TileStats;
use super::super::layers::{self, Layer};
use super::super::ops;
use super::forward::{attend_row, DecodeStats};
use super::PreparedDecoder;

/// Panel fill per continuous decode step — how many slots were live
/// when the step's `[k, d]` GEMV panels ran. `sasp report trace`/`util`
/// surface it as the decode-side utilization evidence.
static M_DECODE_OCC: LazyHistogram = LazyHistogram::new("sasp_decode_batch_occupancy");

/// A retired decode: the slot's utterance id and its generated tokens
/// (BOS/EOS excluded), exactly what [`super::DecoderForward::
/// generate_started`] would have produced for the same utterance.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finished {
    pub id: u64,
    pub tokens: Vec<i32>,
}

/// One in-flight decode: its own KV caches (self-attention grown one
/// row per step, cross-attention fixed at admission) plus the greedy
/// generation state.
struct Slot {
    id: u64,
    src_len: usize,
    /// Steps taken == the position the next token row will occupy.
    pos: usize,
    /// The token fed at the next step (BOS at admission).
    tok: i32,
    out: Vec<i32>,
    self_k: Vec<Vec<f32>>,
    self_v: Vec<Vec<f32>>,
    cross_k: Vec<Vec<f32>>,
    cross_v: Vec<Vec<f32>>,
}

/// The continuous-batching decode runtime: owns up to `max_slots`
/// in-flight decodes and every panel buffer, so steady-state stepping
/// performs no allocation beyond growth to the fullest panel seen.
pub struct ContinuousDecoder {
    max_slots: usize,
    slots: Vec<Slot>,
    /// Slot count of every step taken, in order — the analytic model's
    /// decode schedule ([`crate::sysim::engine::gemm_on_array_decode_batched`]).
    step_batches: Vec<usize>,
    pub stats: DecodeStats,
    // Panel scratch, `[k, ...]` row-major over the live slots.
    h: Vec<f32>,
    hn: Vec<f32>,
    q: Vec<f32>,
    kv: Vec<f32>,
    ctx: Vec<f32>,
    tmp: Vec<f32>,
    mid: Vec<f32>,
    logits: Vec<f32>,
    scores: Vec<f32>,
    wtile: Vec<f32>,
}

impl ContinuousDecoder {
    pub fn new(max_slots: usize) -> Self {
        assert!(max_slots > 0, "need at least one decode slot");
        ContinuousDecoder {
            max_slots,
            slots: Vec::with_capacity(max_slots),
            step_batches: Vec::new(),
            stats: DecodeStats::default(),
            h: Vec::new(),
            hn: Vec::new(),
            q: Vec::new(),
            kv: Vec::new(),
            ctx: Vec::new(),
            tmp: Vec::new(),
            mid: Vec::new(),
            logits: Vec::new(),
            scores: Vec::new(),
            wtile: Vec::new(),
        }
    }

    /// Live (in-flight) slots.
    pub fn live(&self) -> usize {
        self.slots.len()
    }

    pub fn max_slots(&self) -> usize {
        self.max_slots
    }

    /// The per-step slot-count schedule executed so far — feed it to
    /// [`crate::sysim::engine::gemm_on_array_decode_batched`] to
    /// reproduce this run's per-GEMM charges analytically.
    pub fn step_batches(&self) -> &[usize] {
        &self.step_batches
    }

    /// Admit one utterance into a free slot with **externally
    /// precomputed** cross-attention K/V (the serving path batches that
    /// precompute weight-stationary across joiners, exactly like
    /// [`super::DecoderForward::start_with`]): `kv(i)` returns the
    /// block-`i` `(K, V)` slices, each `src_len x d_model`. The caller
    /// owns the precompute's accounting.
    pub fn admit<'a>(
        &mut self,
        m: &PreparedDecoder,
        id: u64,
        src_len: usize,
        kv: impl Fn(usize) -> (&'a [f32], &'a [f32]),
    ) {
        assert!(self.slots.len() < self.max_slots, "no free decode slot");
        assert!(src_len > 0, "empty source");
        let d = m.dims.d_model;
        let n_blocks = m.blocks.len();
        let mut slot = Slot {
            id,
            src_len,
            pos: 0,
            tok: m.dims.bos,
            out: Vec::new(),
            self_k: vec![Vec::new(); n_blocks],
            self_v: vec![Vec::new(); n_blocks],
            cross_k: Vec::with_capacity(n_blocks),
            cross_v: Vec::with_capacity(n_blocks),
        };
        for i in 0..n_blocks {
            let (k, v) = kv(i);
            assert_eq!(k.len(), src_len * d, "block {i} cross-K shape");
            assert_eq!(v.len(), src_len * d, "block {i} cross-V shape");
            slot.cross_k.push(k.to_vec());
            slot.cross_v.push(v.to_vec());
        }
        self.slots.push(slot);
        self.stats.utterances += 1;
    }

    /// Advance every live slot by one token in lockstep: one batched
    /// weight-stationary panel pass per weight GEMM (`batch = live`,
    /// `m = 1`), per-slot attention over each slot's own caches, then
    /// greedy argmax per slot. Slots that emit EOS or reach `max_len`
    /// retire and are returned (in slot order) so the caller can refill
    /// the panel before the next step.
    pub fn step(&mut self, m: &PreparedDecoder) -> Vec<Finished> {
        let k = self.slots.len();
        assert!(k > 0, "step with no live slots");
        let mut span = telemetry::Span::begin("decode.continuous_step");
        let live = telemetry::active();
        let before = if span.is_live() { self.stats.total() } else { TileStats::default() };
        if live {
            M_DECODE_OCC.get().observe(k as u64);
        }
        let dims = &m.dims;
        let (d, v) = (dims.d_model, dims.vocab);

        // Gather the `[k, d]` input panel: per slot, the embedding of
        // the token it is feeding plus that slot's position row — the
        // same two row-wise ops the sequential step performs.
        self.h.clear();
        self.h.resize(k * d, 0.0);
        for (si, slot) in self.slots.iter().enumerate() {
            let p = slot.pos;
            assert!(p < dims.max_len, "slot {} stepped past max_len", slot.id);
            let ti = slot.tok as usize;
            assert!(ti < v, "token {ti} out of vocab {v}");
            let row = &mut self.h[si * d..(si + 1) * d];
            row.copy_from_slice(&m.emb[ti * d..(ti + 1) * d]);
            ops::residual_add(row, &m.pe[p * d..(p + 1) * d]);
        }
        self.ctx.clear();
        self.ctx.resize(k * d, 0.0);

        for (i, blk) in m.blocks.iter().enumerate() {
            // --- causal masked self-attention over each slot's prefix -
            self.hn.clear();
            self.hn.extend_from_slice(&self.h);
            ops::layer_norm(&mut self.hn, d, &blk.ln1_g, &blk.ln1_b);
            let sq = blk.sq.gemm_batched(&self.hn, k, 1, None, m.tile, &mut self.q, &mut self.wtile);
            let sk = blk.sk.gemm_batched(&self.hn, k, 1, None, m.tile, &mut self.kv, &mut self.wtile);
            for (si, slot) in self.slots.iter_mut().enumerate() {
                slot.self_k[i].extend_from_slice(&self.kv[si * d..(si + 1) * d]);
            }
            let sv = blk.sv.gemm_batched(&self.hn, k, 1, None, m.tile, &mut self.kv, &mut self.wtile);
            for (si, slot) in self.slots.iter_mut().enumerate() {
                slot.self_v[i].extend_from_slice(&self.kv[si * d..(si + 1) * d]);
            }
            self.stats.attn.add(&sq);
            self.stats.attn.add(&sk);
            self.stats.attn.add(&sv);
            layers::record(Layer::DecAttn, &sq, m.tile, m.quant);
            layers::record(Layer::DecAttn, &sk, m.tile, m.quant);
            layers::record(Layer::DecAttn, &sv, m.tile, m.quant);
            for (si, slot) in self.slots.iter().enumerate() {
                attend_row(
                    &self.q[si * d..(si + 1) * d],
                    &slot.self_k[i],
                    &slot.self_v[i],
                    slot.pos + 1,
                    d,
                    dims.n_heads,
                    &mut self.scores,
                    &mut self.ctx[si * d..(si + 1) * d],
                );
            }
            let so = blk.so.gemm_batched(&self.ctx, k, 1, None, m.tile, &mut self.tmp, &mut self.wtile);
            self.stats.attn.add(&so);
            layers::record(Layer::DecAttn, &so, m.tile, m.quant);
            ops::residual_add(&mut self.h, &self.tmp);

            // --- encoder-decoder cross-attention (K/V from admission) -
            self.hn.clear();
            self.hn.extend_from_slice(&self.h);
            ops::layer_norm(&mut self.hn, d, &blk.lnx_g, &blk.lnx_b);
            let xq = blk.xq.gemm_batched(&self.hn, k, 1, None, m.tile, &mut self.q, &mut self.wtile);
            self.stats.attn.add(&xq);
            layers::record(Layer::DecAttn, &xq, m.tile, m.quant);
            for (si, slot) in self.slots.iter().enumerate() {
                attend_row(
                    &self.q[si * d..(si + 1) * d],
                    &slot.cross_k[i],
                    &slot.cross_v[i],
                    slot.src_len,
                    d,
                    dims.n_heads,
                    &mut self.scores,
                    &mut self.ctx[si * d..(si + 1) * d],
                );
            }
            let xo = blk.xo.gemm_batched(&self.ctx, k, 1, None, m.tile, &mut self.tmp, &mut self.wtile);
            self.stats.attn.add(&xo);
            layers::record(Layer::DecAttn, &xo, m.tile, m.quant);
            ops::residual_add(&mut self.h, &self.tmp);

            // --- pre-LN SASP feed-forward -----------------------------
            self.hn.clear();
            self.hn.extend_from_slice(&self.h);
            ops::layer_norm(&mut self.hn, d, &blk.ln2_g, &blk.ln2_b);
            let mut ff_span = telemetry::Span::begin("gemm.decode_ff");
            let s1 =
                blk.w1.gemm_batched(&self.hn, k, 1, Some(&blk.mask1), m.tile, &mut self.mid, &mut self.wtile);
            self.stats.ff.add(&s1);
            layers::record(Layer::DecFf, &s1, m.tile, m.quant);
            ops::add_bias(&mut self.mid, &blk.b1);
            ops::relu(&mut self.mid);
            let s2 =
                blk.w2.gemm_batched(&self.mid, k, 1, Some(&blk.mask2), m.tile, &mut self.tmp, &mut self.wtile);
            self.stats.ff.add(&s2);
            layers::record(Layer::DecFf, &s2, m.tile, m.quant);
            if ff_span.is_live() {
                ff_span.attr("block", i);
                ff_span.attr("slots", k);
                let mut ff = s1;
                ff.add(&s2);
                ff.annotate(&mut ff_span);
            }
            drop(ff_span);
            ops::add_bias(&mut self.tmp, &blk.b2);
            ops::residual_add(&mut self.h, &self.tmp);
        }

        self.hn.clear();
        self.hn.extend_from_slice(&self.h);
        ops::layer_norm(&mut self.hn, d, &m.lnf_g, &m.lnf_b);
        let st = gemm_batched_f32(
            &self.hn, &m.head_w, k, 1, d, v, None, m.tile, &mut self.logits, &mut self.wtile,
        );
        self.stats.other.add(&st);
        layers::record(Layer::Head, &st, m.tile, Quant::Fp32);
        ops::add_bias(&mut self.logits, &m.head_b);
        self.stats.steps += k;
        self.step_batches.push(k);

        // Greedy argmax per slot (first-max-wins, the sequential tie
        // rule), then retire EOS'd and max-len'd slots in slot order.
        let mut finished = Vec::new();
        let logits = &self.logits;
        let (eos, max_len) = (dims.eos, dims.max_len);
        let mut si = 0usize;
        self.slots.retain_mut(|slot| {
            let row = &logits[si * v..(si + 1) * v];
            si += 1;
            let mut best = 0usize;
            for (j, l) in row.iter().enumerate() {
                if *l > row[best] {
                    best = j;
                }
            }
            let next = best as i32;
            slot.pos += 1;
            if next == eos {
                finished.push(Finished { id: slot.id, tokens: std::mem::take(&mut slot.out) });
                return false;
            }
            slot.out.push(next);
            slot.tok = next;
            if slot.pos == max_len {
                finished.push(Finished { id: slot.id, tokens: std::mem::take(&mut slot.out) });
                return false;
            }
            true
        });
        if span.is_live() {
            span.attr("slots", k);
            span.attr("retired", finished.len());
            self.stats.total().minus(&before).annotate(&mut span);
        }
        finished
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{mini_dec_dims, random_dec_masks};
    use super::super::{DecoderDims, DecoderForward, PreparedDecoder};
    use super::*;
    use crate::infer::synth::synth_decoder_weights;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn random_memory(rng: &mut Rng, src_len: usize, d: usize) -> Vec<f32> {
        (0..src_len * d).map(|_| rng.normal() as f32 * 0.5).collect()
    }

    /// Per-utterance, per-block cross K/V precomputed with the same
    /// kernels the sequential path uses (see
    /// `start_with_precomputed_kv_matches_start`).
    fn cross_kv(m: &PreparedDecoder, mems: &[(Vec<f32>, usize)]) -> Vec<Vec<(Vec<f32>, Vec<f32>)>> {
        mems.iter()
            .map(|(memory, src_len)| {
                m.blocks
                    .iter()
                    .map(|blk| {
                        let mut k = Vec::new();
                        let mut v = Vec::new();
                        blk.xk.gemm(memory, *src_len, None, m.tile, &mut k);
                        blk.xv.gemm(memory, *src_len, None, m.tile, &mut v);
                        (k, v)
                    })
                    .collect()
            })
            .collect()
    }

    /// Drive a continuous run over `mems` in arrival order with a FIFO
    /// refill queue — the step loop every caller (backend, server,
    /// harness) uses. Returns the per-utterance outputs plus the
    /// decoder for schedule/stats inspection.
    fn run_continuous(
        m: &PreparedDecoder,
        mems: &[(Vec<f32>, usize)],
        max_slots: usize,
    ) -> (Vec<Vec<i32>>, ContinuousDecoder) {
        let kv = cross_kv(m, mems);
        let mut cd = ContinuousDecoder::new(max_slots);
        let mut outs: Vec<Option<Vec<i32>>> = vec![None; mems.len()];
        let mut next = 0usize;
        loop {
            while cd.live() < max_slots && next < mems.len() {
                let u = next;
                cd.admit(m, u as u64, mems[u].1, |i| {
                    (kv[u][i].0.as_slice(), kv[u][i].1.as_slice())
                });
                next += 1;
            }
            if cd.live() == 0 {
                break;
            }
            for f in cd.step(m) {
                let slot = &mut outs[f.id as usize];
                assert!(slot.is_none(), "utterance {} retired twice", f.id);
                *slot = Some(f.tokens);
            }
        }
        (outs.into_iter().map(Option::unwrap).collect(), cd)
    }

    /// Sequential greedy oracle: one utterance at a time on the
    /// per-utterance engine.
    fn sequential(m: &PreparedDecoder, mems: &[(Vec<f32>, usize)]) -> Vec<Vec<i32>> {
        let mut fwd = DecoderForward::new();
        let mut outs = Vec::new();
        for (memory, src_len) in mems {
            let mut out = Vec::new();
            fwd.generate(m, memory, *src_len, &mut out);
            outs.push(out);
        }
        outs
    }

    fn random_mems(rng: &mut Rng, n: usize, d: usize) -> Vec<(Vec<f32>, usize)> {
        (0..n)
            .map(|_| {
                let src_len = rng.index(10) + 2;
                (random_memory(rng, src_len, d), src_len)
            })
            .collect()
    }

    #[test]
    fn single_slot_continuous_run_equals_plain_greedy() {
        // Lifecycle satellite: with one slot the continuous scheduler
        // degenerates to sequential greedy decode — same tokens, one
        // slot per step.
        let dims = mini_dec_dims();
        let w = synth_decoder_weights(&dims, 43);
        let m = PreparedDecoder::new(&w, dims.tile, crate::systolic::Quant::Fp32, None).unwrap();
        let mut rng = Rng::new(47);
        let mems = random_mems(&mut rng, 3, dims.d_model);
        let (got, cd) = run_continuous(&m, &mems, 1);
        assert_eq!(got, sequential(&m, &mems));
        assert!(cd.step_batches().iter().all(|&k| k == 1));
        assert_eq!(cd.stats.utterances, 3);
        assert_eq!(cd.stats.steps, cd.step_batches().len());
    }

    #[test]
    fn eos_at_step_zero_retires_the_whole_panel_and_refills() {
        // Lifecycle satellite: EOS at step 0 + all slots retiring on
        // the same step. A head biased hard toward EOS retires every
        // slot after one step; the queue refills the panel until empty.
        let dims = mini_dec_dims();
        let mut w = synth_decoder_weights(&dims, 53);
        w.head_b[dims.eos as usize] = 1e6;
        let m = PreparedDecoder::new(&w, dims.tile, crate::systolic::Quant::Fp32, None).unwrap();
        let mut rng = Rng::new(59);
        let mems = random_mems(&mut rng, 5, dims.d_model);
        let (got, cd) = run_continuous(&m, &mems, 2);
        assert!(got.iter().all(|o| o.is_empty()), "EOS-first: empty outputs");
        assert_eq!(cd.step_batches(), &[2, 2, 1], "full panels until the queue drains");
        assert_eq!(cd.stats.utterances, 5);
        assert_eq!(cd.stats.steps, 5);
    }

    #[test]
    fn max_len_hit_with_nonempty_queue_then_queue_drains_mid_decode() {
        // Lifecycle satellite: max-len retirement while the queue still
        // holds work, then the drained queue shrinks the panel. A head
        // biased hard against EOS runs every slot to max_len: utterances
        // 0+1 share full panels for max_len steps (utterance 2 queued),
        // then utterance 2 decodes alone.
        let dims = mini_dec_dims();
        let mut w = synth_decoder_weights(&dims, 61);
        w.head_b[dims.eos as usize] = -1e6;
        let m = PreparedDecoder::new(&w, dims.tile, crate::systolic::Quant::Fp32, None).unwrap();
        let mut rng = Rng::new(67);
        let mems = random_mems(&mut rng, 3, dims.d_model);
        let (got, cd) = run_continuous(&m, &mems, 2);
        assert!(got.iter().all(|o| o.len() == dims.max_len), "no EOS: max_len outputs");
        assert_eq!(got, sequential(&m, &mems));
        let mut want = vec![2usize; dims.max_len];
        want.extend(vec![1usize; dims.max_len]);
        assert_eq!(cd.step_batches(), &want[..]);
    }

    #[test]
    fn prop_continuous_decode_bitwise_equals_sequential_greedy() {
        // The tentpole contract: under random join/leave schedules
        // (random utterance count, slot count, source lengths, masks,
        // both weight formats), every utterance's continuous output is
        // bitwise identical to decoding it alone.
        check("continuous batched decode == sequential greedy", 10, |rng: &mut Rng| {
            let dims = mini_dec_dims();
            let quant = if rng.chance(0.5) {
                crate::systolic::Quant::Fp32
            } else {
                crate::systolic::Quant::Int8
            };
            let w = synth_decoder_weights(&dims, rng.next_u64());
            let masks = random_dec_masks(&dims, dims.tile, 0.35, rng.next_u64());
            let m = PreparedDecoder::new(&w, dims.tile, quant, Some(&masks)).unwrap();
            let n = rng.index(6) + 1;
            let max_slots = rng.index(4) + 1;
            let mems = random_mems(rng, n, dims.d_model);
            let (got, cd) = run_continuous(&m, &mems, max_slots);
            let want = sequential(&m, &mems);
            if got != want {
                return (false, format!("{quant:?} n={n} slots={max_slots}"));
            }
            let steps: usize = cd.step_batches().iter().sum();
            (
                cd.stats.steps == steps && cd.stats.utterances == n,
                format!("schedule sums to steps: {quant:?} n={n} slots={max_slots}"),
            )
        });
    }

    #[test]
    fn continuous_accounting_matches_analytic_decode_batched() {
        // Functional x analytic at step AND run scope: the batched
        // panel charges must equal `gemm_on_array_decode_batched` over
        // the recorded slot-count schedule, cumulatively after every
        // step. Uses a vocab that is a multiple of the tile so the
        // software-f32 head cross-checks exactly too.
        use crate::model::{GemmKind, GemmShape};
        use crate::sysim::engine::gemm_on_array_decode_batched;
        use crate::sysim::SimParams;
        use crate::systolic::ArrayConfig;

        let dims = DecoderDims {
            vocab: 16,
            d_model: 32,
            n_heads: 4,
            d_ff: 64,
            n_blocks: 2,
            max_len: 6,
            tile: 8,
            bos: 1,
            eos: 2,
        };
        let mut w = synth_decoder_weights(&dims, 71);
        w.head_b[dims.eos as usize] = -1e6; // run every slot to max_len
        let masks = random_dec_masks(&dims, dims.tile, 0.5, 73);
        let m =
            PreparedDecoder::new(&w, dims.tile, crate::systolic::Quant::Int8, Some(&masks)).unwrap();
        let mut rng = Rng::new(79);
        let mems = random_mems(&mut rng, 3, dims.d_model);
        let kv = cross_kv(&m, &mems);

        // Step manually so we can snapshot the cumulative charges after
        // every step (run scope == the last snapshot).
        let max_slots = 2usize;
        let mut cd = ContinuousDecoder::new(max_slots);
        let mut next = 0usize;
        let mut snaps = Vec::new();
        loop {
            while cd.live() < max_slots && next < mems.len() {
                let u = next;
                cd.admit(&m, u as u64, mems[u].1, |i| {
                    (kv[u][i].0.as_slice(), kv[u][i].1.as_slice())
                });
                next += 1;
            }
            if cd.live() == 0 {
                break;
            }
            cd.step(&m);
            snaps.push((cd.stats.ff, cd.stats.attn, cd.stats.other));
        }
        let schedule = cd.step_batches().to_vec();
        assert_eq!(snaps.len(), schedule.len());
        assert!(schedule.contains(&2) && schedule.contains(&1), "want a ragged schedule");
        assert_eq!(cd.stats.cross_kv, crate::infer::TileStats::default());

        let cfg = ArrayConfig::square(dims.tile, crate::systolic::Quant::Int8);
        let cfg_f32 = ArrayConfig::square(dims.tile, crate::systolic::Quant::Fp32);
        let p = SimParams::default();
        let (d, f, v) = (dims.d_model, dims.d_ff, dims.vocab);
        let proj = GemmShape { m: 1, k: d, n: d, kind: GemmKind::AttnProj };
        let head = GemmShape { m: 1, k: d, n: v, kind: GemmKind::AttnProj };
        for (s, (ff, attn, other)) in snaps.iter().enumerate() {
            let sched = &schedule[..=s];
            let mut ff_want = crate::sysim::engine::GemmCost::default();
            let mut attn_want = crate::sysim::engine::GemmCost::default();
            for i in 0..dims.n_blocks {
                let g1 = GemmShape { m: 1, k: d, n: f, kind: GemmKind::FeedForward };
                let g2 = GemmShape { m: 1, k: f, n: d, kind: GemmKind::FeedForward };
                ff_want.add(&gemm_on_array_decode_batched(&g1, &cfg, &p, Some(&masks[2 * i]), sched));
                ff_want.add(&gemm_on_array_decode_batched(&g2, &cfg, &p, Some(&masks[2 * i + 1]), sched));
                // sq sk sv so xq xo: six panel projections per block.
                let cp = gemm_on_array_decode_batched(&proj, &cfg, &p, None, sched);
                for _ in 0..6 {
                    attn_want.add(&cp);
                }
            }
            let head_want = gemm_on_array_decode_batched(&head, &cfg_f32, &p, None, sched);
            assert_eq!(ff.timing.macs as u64, ff_want.counts.macs, "ff macs @ step {s}");
            assert_eq!(ff.timing.total_words() as u64, ff_want.counts.bus_words, "ff words @ step {s}");
            assert_eq!(ff.timing.array_cycles as u64, ff_want.counts.array_busy_cycles, "ff cycles @ step {s}");
            assert_eq!(attn.timing.macs as u64, attn_want.counts.macs, "attn macs @ step {s}");
            assert_eq!(attn.timing.total_words() as u64, attn_want.counts.bus_words, "attn words @ step {s}");
            assert_eq!(attn.timing.array_cycles as u64, attn_want.counts.array_busy_cycles, "attn cycles @ step {s}");
            assert_eq!(other.timing.macs as u64, head_want.counts.macs, "head macs @ step {s}");
            assert_eq!(other.timing.total_words() as u64, head_want.counts.bus_words, "head words @ step {s}");
            assert_eq!(other.timing.array_cycles as u64, head_want.counts.array_busy_cycles, "head cycles @ step {s}");
        }
        // The skip schedule: each live/dead ff tile once per step,
        // independent of panel fill.
        let live: usize = masks.iter().map(crate::sysim::TileMask::live_count).sum();
        let dead: usize = masks.iter().map(|mk| mk.n_tiles() - mk.live_count()).sum();
        assert!(live > 0 && dead > 0);
        assert_eq!(cd.stats.ff.tiles_live, schedule.len() * live);
        assert_eq!(cd.stats.ff.tiles_skipped, schedule.len() * dead);
    }
}
