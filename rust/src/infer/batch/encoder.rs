//! The batched encoder forward pass — [`BatchForward`], the serving
//! runtime's counterpart of the per-utterance
//! [`crate::infer::encoder::Forward`].
//!
//! All weight GEMMs (attention projections, the SASP feed-forward pair,
//! input projection and vocabulary head) run flattened over the
//! `[batch*seq, d]` panel through the weight-stationary kernels of
//! [`super::gemm`], so every live tile is loaded once per batch. The
//! softmax-attention core is inherently per-utterance (scores are
//! `seq x seq` within one utterance) and runs per utterance with that
//! utterance's pad mask — exactly the arithmetic of the per-utterance
//! engine, which is what keeps the whole batched forward **bitwise
//! identical** to running the utterances one at a time (ragged pad
//! tails included; asserted in the tests below).
//!
//! Buffers are owned and reused, so steady-state serving performs no
//! allocation beyond growth to the largest batch seen.

use crate::systolic::Quant;

use super::super::encoder::{ForwardStats, PreparedModel};
use super::super::layers::{self, Layer};
use super::super::ops;
use super::gemm::gemm_batched_f32;

/// The batched forward-pass runtime: owns every intermediate buffer
/// (sized `batch * seq` rows) plus the tile-packing scratch.
pub struct BatchForward {
    h: Vec<f32>,
    hn: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    scores: Vec<f32>,
    ctx: Vec<f32>,
    tmp: Vec<f32>,
    mid: Vec<f32>,
    /// Pad-mask buffer for the token (MT) path, rebuilt per call from
    /// the batch's real source lengths, reused across calls.
    pad_buf: Vec<f32>,
    /// Packed-tile scratch of the weight-stationary kernels.
    wtile: Vec<f32>,
    pub stats: ForwardStats,
}

impl Default for BatchForward {
    fn default() -> Self {
        BatchForward::new()
    }
}

impl BatchForward {
    pub fn new() -> Self {
        BatchForward {
            h: Vec::new(),
            hn: Vec::new(),
            q: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
            scores: Vec::new(),
            ctx: Vec::new(),
            tmp: Vec::new(),
            mid: Vec::new(),
            pad_buf: Vec::new(),
            wtile: Vec::new(),
            stats: ForwardStats::default(),
        }
    }

    /// ASR: one padded batch of `batch x seq_len x input_dim` features
    /// with a `batch x seq_len` validity mask → CTC log-probs
    /// `batch x seq_len x vocab` (flattened) in `out`.
    pub fn run_feats(
        &mut self,
        m: &PreparedModel,
        batch: usize,
        feats: &[f32],
        pad: &[f32],
        out: &mut Vec<f32>,
    ) {
        let dims = &m.dims;
        assert!(!dims.token_input, "feature input on a token-input model");
        assert!(batch > 0, "batch must be positive");
        let t = dims.seq_len;
        assert_eq!(
            feats.len(),
            batch * t * dims.input_dim,
            "feats must be batch x seq x input"
        );
        assert_eq!(pad.len(), batch * t, "pad mask must be batch x seq");
        let st = gemm_batched_f32(
            feats,
            &m.in_w,
            batch,
            t,
            dims.input_dim,
            dims.d_model,
            None,
            m.tile,
            &mut self.h,
            &mut self.wtile,
        );
        self.stats.other.add(&st);
        // The projection runs in FP32 regardless of the kernel format.
        layers::record(Layer::InProj, &st, m.tile, Quant::Fp32);
        self.encode(m, batch, pad);
        self.head(m, batch, out, true);
        self.stats.utterances += batch;
    }

    /// MT: one batch of full-length `batch x seq_len` token sentences →
    /// per-position logits `batch x seq_len x vocab` in `out`.
    pub fn run_tokens(
        &mut self,
        m: &PreparedModel,
        batch: usize,
        tokens: &[i32],
        out: &mut Vec<f32>,
    ) {
        let lens = vec![m.dims.seq_len; batch];
        self.run_tokens_padded(m, batch, tokens, &lens, out);
    }

    /// MT with a ragged batch: utterance `u` has `src_len[u]` real
    /// tokens; the pad tails are masked out of attention, so each
    /// utterance's valid-prefix logits are bitwise identical to the
    /// per-utterance padded run.
    pub fn run_tokens_padded(
        &mut self,
        m: &PreparedModel,
        batch: usize,
        tokens: &[i32],
        src_len: &[usize],
        out: &mut Vec<f32>,
    ) {
        self.embed_encode_tokens(m, batch, tokens, src_len);
        self.head(m, batch, out, false);
        self.stats.utterances += batch;
    }

    /// Batched MT encoder memory for decoder cross-attention: embed +
    /// encode the ragged batch and write the post-final-LayerNorm hidden
    /// states `batch x seq_len x d_model` (flattened) into `memory`.
    /// Rows beyond each utterance's `src_len` are pad rows.
    pub fn memory_tokens(
        &mut self,
        m: &PreparedModel,
        batch: usize,
        tokens: &[i32],
        src_len: &[usize],
        memory: &mut Vec<f32>,
    ) {
        self.embed_encode_tokens(m, batch, tokens, src_len);
        memory.clear();
        memory.extend_from_slice(&self.h);
        ops::layer_norm(memory, m.dims.d_model, &m.lnf_g, &m.lnf_b);
        self.stats.utterances += batch;
    }

    /// Shared token path: embed the batch, build the real pad masks from
    /// `src_len`, and run the encoder stack.
    fn embed_encode_tokens(
        &mut self,
        m: &PreparedModel,
        batch: usize,
        tokens: &[i32],
        src_len: &[usize],
    ) {
        let dims = &m.dims;
        assert!(dims.token_input, "token input on a feature-input model");
        assert!(batch > 0, "batch must be positive");
        let t = dims.seq_len;
        assert_eq!(tokens.len(), batch * t, "tokens must be batch x seq");
        assert_eq!(src_len.len(), batch, "one src_len per utterance");
        let d = dims.d_model;
        self.h.clear();
        self.h.resize(batch * t * d, 0.0);
        for (row, tok) in tokens.iter().enumerate() {
            let ti = *tok as usize;
            assert!(ti < dims.vocab, "token {ti} out of vocab {}", dims.vocab);
            self.h[row * d..(row + 1) * d].copy_from_slice(&m.in_w[ti * d..(ti + 1) * d]);
        }
        let mut pad = std::mem::take(&mut self.pad_buf);
        pad.clear();
        pad.resize(batch * t, 0.0);
        for (u, &len) in src_len.iter().enumerate() {
            assert!(len > 0 && len <= t, "src_len {len} out of 1..={t}");
            for p in pad[u * t..u * t + len].iter_mut() {
                *p = 1.0;
            }
        }
        self.encode(m, batch, &pad);
        self.pad_buf = pad;
    }

    /// Shared encoder stack over `self.h` (the projected / embedded
    /// input of the whole batch, before bias + positions).
    fn encode(&mut self, m: &PreparedModel, batch: usize, pad: &[f32]) {
        let dims = &m.dims;
        let (t, d) = (dims.seq_len, dims.d_model);
        let rows = batch * t;
        let (h_heads, hd) = (dims.n_heads, dims.head_dim());
        let inv_sqrt_hd = 1.0 / (hd as f32).sqrt();
        ops::add_bias(&mut self.h, &m.in_b);
        for u in 0..batch {
            ops::residual_add(&mut self.h[u * t * d..(u + 1) * t * d], &m.pe);
        }
        self.scores.clear();
        self.scores.resize(t * t, 0.0);
        self.ctx.clear();
        self.ctx.resize(rows * d, 0.0);

        for blk in &m.blocks {
            // --- pre-LN multi-head self-attention ------------------------
            self.hn.clear();
            self.hn.extend_from_slice(&self.h);
            ops::layer_norm(&mut self.hn, d, &blk.ln1_g, &blk.ln1_b);
            let sq = blk
                .wq
                .gemm_batched(&self.hn, batch, t, None, m.tile, &mut self.q, &mut self.wtile);
            let sk = blk
                .wk
                .gemm_batched(&self.hn, batch, t, None, m.tile, &mut self.k, &mut self.wtile);
            let sv = blk
                .wv
                .gemm_batched(&self.hn, batch, t, None, m.tile, &mut self.v, &mut self.wtile);
            self.stats.attn.add(&sq);
            self.stats.attn.add(&sk);
            self.stats.attn.add(&sv);
            layers::record(Layer::Qkv, &sq, m.tile, m.quant);
            layers::record(Layer::Qkv, &sk, m.tile, m.quant);
            layers::record(Layer::Qkv, &sv, m.tile, m.quant);
            // The dynamic score/context GEMMs are per-utterance by
            // construction (activation x activation within one
            // utterance; software FP32, never pruned).
            for u in 0..batch {
                let base = u * t * d;
                let pad_u = &pad[u * t..(u + 1) * t];
                for head in 0..h_heads {
                    let c0 = head * hd;
                    for a in 0..t {
                        for b in 0..t {
                            let mut acc = 0.0f32;
                            for j in 0..hd {
                                acc += self.q[base + a * d + c0 + j]
                                    * self.k[base + b * d + c0 + j];
                            }
                            self.scores[a * t + b] =
                                acc * inv_sqrt_hd + (1.0 - pad_u[b]) * -1e9;
                        }
                    }
                    ops::softmax_rows(&mut self.scores, t);
                    for a in 0..t {
                        for j in 0..hd {
                            let mut acc = 0.0f32;
                            for b in 0..t {
                                acc += self.scores[a * t + b]
                                    * self.v[base + b * d + c0 + j];
                            }
                            self.ctx[base + a * d + c0 + j] = acc;
                        }
                    }
                }
            }
            let so = blk
                .wo
                .gemm_batched(&self.ctx, batch, t, None, m.tile, &mut self.tmp, &mut self.wtile);
            self.stats.attn.add(&so);
            layers::record(Layer::AttnOut, &so, m.tile, m.quant);
            ops::residual_add(&mut self.h, &self.tmp);

            // --- pre-LN SASP feed-forward --------------------------------
            self.hn.clear();
            self.hn.extend_from_slice(&self.h);
            ops::layer_norm(&mut self.hn, d, &blk.ln2_g, &blk.ln2_b);
            let s1 = blk.w1.gemm_batched(
                &self.hn,
                batch,
                t,
                Some(&blk.mask1),
                m.tile,
                &mut self.mid,
                &mut self.wtile,
            );
            self.stats.ff.add(&s1);
            layers::record(Layer::Ff1, &s1, m.tile, m.quant);
            ops::add_bias(&mut self.mid, &blk.b1);
            ops::relu(&mut self.mid);
            let s2 = blk.w2.gemm_batched(
                &self.mid,
                batch,
                t,
                Some(&blk.mask2),
                m.tile,
                &mut self.tmp,
                &mut self.wtile,
            );
            self.stats.ff.add(&s2);
            layers::record(Layer::Ff2, &s2, m.tile, m.quant);
            ops::add_bias(&mut self.tmp, &blk.b2);
            ops::residual_add(&mut self.h, &self.tmp);
        }
    }

    /// Final LayerNorm + vocabulary head (+ log-softmax for CTC).
    fn head(&mut self, m: &PreparedModel, batch: usize, out: &mut Vec<f32>, log_probs: bool) {
        let dims = &m.dims;
        let (t, d, v) = (dims.seq_len, dims.d_model, dims.vocab);
        self.hn.clear();
        self.hn.extend_from_slice(&self.h);
        ops::layer_norm(&mut self.hn, d, &m.lnf_g, &m.lnf_b);
        let st = gemm_batched_f32(
            &self.hn,
            &m.head_w,
            batch,
            t,
            d,
            v,
            None,
            m.tile,
            out,
            &mut self.wtile,
        );
        self.stats.other.add(&st);
        layers::record(Layer::Head, &st, m.tile, Quant::Fp32);
        ops::add_bias(out, &m.head_b);
        if log_probs {
            ops::log_softmax_rows(out, v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::encoder::{EncoderWeights, Forward, ModelDims};
    use crate::infer::testutil::mini_dims;
    use crate::model::{GemmKind, GemmShape};
    use crate::sysim::engine::gemm_on_array_batched;
    use crate::sysim::{SimParams, TileMask};
    use crate::systolic::{ArrayConfig, Quant};
    use crate::util::rng::Rng;

    fn random_masks(dims: &ModelDims, tile: usize, p_dead: f64, seed: u64) -> Vec<TileMask> {
        let mut rng = Rng::new(seed);
        let (kt, nt) = (dims.d_model / tile, dims.d_ff / tile);
        let mut out = Vec::new();
        for _ in 0..dims.n_blocks {
            out.push(TileMask {
                kt,
                nt,
                live: (0..kt * nt).map(|_| !rng.chance(p_dead)).collect(),
            });
            out.push(TileMask {
                kt: nt,
                nt: kt,
                live: (0..kt * nt).map(|_| !rng.chance(p_dead)).collect(),
            });
        }
        out
    }

    /// A ragged batch: random features, per-utterance valid lengths
    /// covering full, half, and near-empty tails.
    fn ragged_batch(dims: &ModelDims, batch: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let t = dims.seq_len;
        let feats: Vec<f32> = (0..batch * t * dims.input_dim)
            .map(|_| rng.normal() as f32 * 0.5)
            .collect();
        let mut pad = vec![0.0f32; batch * t];
        for u in 0..batch {
            let len = match u % 3 {
                0 => t,
                1 => t / 2,
                _ => 1 + rng.index(t - 1),
            };
            for tt in 0..len {
                pad[u * t + tt] = 1.0;
            }
        }
        (feats, pad)
    }

    fn prepared(w: &EncoderWeights, quant: Quant, seed: u64) -> PreparedModel {
        let dims = w.dims;
        let masks = random_masks(&dims, dims.tile, 0.4, seed);
        PreparedModel::new(w, dims.tile, quant, Some(&masks)).unwrap()
    }

    /// The satellite contract: batched == per-utterance, value-exact —
    /// bitwise for FP32, and bitwise for INT8 too (identical FP op
    /// sequences), ragged pad tails included.
    fn assert_batched_equals_per_utterance(quant: Quant) {
        let dims = mini_dims();
        let w = crate::infer::synth::synth_weights(&dims, 33);
        let model = prepared(&w, quant, 35);
        let batch = 5usize; // deliberately not a multiple of the 4-row microkernel block
        let (feats, pad) = ragged_batch(&dims, batch, 37);
        let (t, f, v) = (dims.seq_len, dims.input_dim, dims.vocab);

        let mut bf = BatchForward::new();
        let mut got = Vec::new();
        bf.run_feats(&model, batch, &feats, &pad, &mut got);
        assert_eq!(got.len(), batch * t * v);

        let mut fwd = Forward::new();
        let mut row = Vec::new();
        for u in 0..batch {
            fwd.run_feats(
                &model,
                &feats[u * t * f..(u + 1) * t * f],
                &pad[u * t..(u + 1) * t],
                &mut row,
            );
            assert_eq!(
                &got[u * t * v..(u + 1) * t * v],
                row.as_slice(),
                "{quant:?}: utterance {u} must match bitwise"
            );
        }
        assert_eq!(bf.stats.utterances, batch);
        assert_eq!(fwd.stats.utterances, batch);
        // Identical skip schedule; batched programming amortized.
        assert_eq!(bf.stats.ff.tiles_live * batch, fwd.stats.ff.tiles_live);
        assert_eq!(bf.stats.ff.tiles_skipped * batch, fwd.stats.ff.tiles_skipped);
        assert_eq!(bf.stats.ff.timing.macs, fwd.stats.ff.timing.macs);
        assert_eq!(bf.stats.ff.timing.in_words, fwd.stats.ff.timing.in_words);
        assert_eq!(
            bf.stats.ff.timing.prog_words * batch,
            fwd.stats.ff.timing.prog_words,
            "weight-stationary reuse: one programming pass per batch"
        );
        assert_eq!(bf.stats.attn.timing.macs, fwd.stats.attn.timing.macs);
    }

    #[test]
    fn batched_forward_bitwise_equals_per_utterance_fp32() {
        assert_batched_equals_per_utterance(Quant::Fp32);
    }

    #[test]
    fn batched_forward_value_exact_per_utterance_int8() {
        assert_batched_equals_per_utterance(Quant::Int8);
    }

    #[test]
    fn batched_forward_per_channel_int8_matches_per_utterance() {
        let dims = mini_dims();
        let w = crate::infer::synth::synth_weights(&dims, 41);
        let masks = random_masks(&dims, dims.tile, 0.3, 43);
        let model =
            PreparedModel::new_with(&w, dims.tile, Quant::Int8, Some(&masks), true).unwrap();
        let batch = 3usize;
        let (feats, pad) = ragged_batch(&dims, batch, 45);
        let (t, f, v) = (dims.seq_len, dims.input_dim, dims.vocab);
        let mut bf = BatchForward::new();
        let mut got = Vec::new();
        bf.run_feats(&model, batch, &feats, &pad, &mut got);
        let mut fwd = Forward::new();
        let mut row = Vec::new();
        for u in 0..batch {
            fwd.run_feats(
                &model,
                &feats[u * t * f..(u + 1) * t * f],
                &pad[u * t..(u + 1) * t],
                &mut row,
            );
            assert_eq!(&got[u * t * v..(u + 1) * t * v], row.as_slice(), "utt {u}");
        }
    }

    #[test]
    fn batched_tokens_equal_per_utterance() {
        let dims = ModelDims {
            token_input: true,
            ctc_blank: -1,
            ..mini_dims()
        };
        let w = crate::infer::synth::synth_weights(&dims, 47);
        let model = prepared(&w, Quant::Fp32, 49);
        let batch = 3usize;
        let t = dims.seq_len;
        let mut rng = Rng::new(8);
        let tokens: Vec<i32> = (0..batch * t)
            .map(|_| rng.index(dims.vocab) as i32)
            .collect();
        let mut bf = BatchForward::new();
        let mut got = Vec::new();
        bf.run_tokens(&model, batch, &tokens, &mut got);
        let mut fwd = Forward::new();
        let mut row = Vec::new();
        let v = dims.vocab;
        for u in 0..batch {
            fwd.run_tokens(&model, &tokens[u * t..(u + 1) * t], &mut row);
            assert_eq!(&got[u * t * v..(u + 1) * t * v], row.as_slice(), "utt {u}");
        }
    }

    #[test]
    fn ragged_token_batch_equals_per_utterance_padded() {
        // Satellite: real source pad masks through the batched token
        // path — each utterance of a ragged batch is bitwise identical
        // to its per-utterance padded run, logits and memory both.
        let dims = ModelDims {
            token_input: true,
            ctc_blank: -1,
            ..mini_dims()
        };
        let w = crate::infer::synth::synth_weights(&dims, 71);
        let model = prepared(&w, Quant::Fp32, 73);
        let batch = 3usize;
        let t = dims.seq_len;
        let mut rng = Rng::new(12);
        let tokens: Vec<i32> = (0..batch * t)
            .map(|_| rng.index(dims.vocab) as i32)
            .collect();
        let lens = vec![t, t / 2, t / 3 + 1];
        let mut bf = BatchForward::new();
        let mut got = Vec::new();
        bf.run_tokens_padded(&model, batch, &tokens, &lens, &mut got);
        let mut bmem = Vec::new();
        bf.memory_tokens(&model, batch, &tokens, &lens, &mut bmem);
        let (d, v) = (dims.d_model, dims.vocab);
        let mut fwd = Forward::new();
        let mut row = Vec::new();
        let mut mem = Vec::new();
        for u in 0..batch {
            fwd.run_tokens_padded(&model, &tokens[u * t..(u + 1) * t], lens[u], &mut row);
            assert_eq!(
                &got[u * t * v..u * t * v + lens[u] * v],
                &row[..lens[u] * v],
                "utt {u} logits"
            );
            fwd.memory_tokens(&model, &tokens[u * t..(u + 1) * t], lens[u], &mut mem);
            assert_eq!(
                &bmem[u * t * d..u * t * d + lens[u] * d],
                &mem[..lens[u] * d],
                "utt {u} memory"
            );
        }
    }

    #[test]
    fn batched_stats_match_analytic_batched_accounting() {
        // The ff schedule the batched forward executed must cost exactly
        // what the analytic engine charges for the same GEMMs + masks at
        // the same batch — the encoder-scope functional x analytic
        // cross-check of the reuse model.
        let dims = mini_dims();
        let tile = dims.tile;
        let w = crate::infer::synth::synth_weights(&dims, 61);
        let masks = random_masks(&dims, tile, 0.5, 63);
        let model = PreparedModel::new(&w, tile, Quant::Int8, Some(&masks)).unwrap();
        let batch = 4usize;
        let (feats, pad) = ragged_batch(&dims, batch, 65);
        let mut bf = BatchForward::new();
        let mut out = Vec::new();
        bf.run_feats(&model, batch, &feats, &pad, &mut out);

        let cfg = ArrayConfig::square(tile, Quant::Int8);
        let p = SimParams::default();
        let (t, d, f) = (dims.seq_len, dims.d_model, dims.d_ff);
        let mut macs = 0u64;
        let mut bus_words = 0u64;
        let mut array_cycles = 0u64;
        for i in 0..dims.n_blocks {
            let g1 = GemmShape { m: t, k: d, n: f, kind: GemmKind::FeedForward };
            let g2 = GemmShape { m: t, k: f, n: d, kind: GemmKind::FeedForward };
            let c1 = gemm_on_array_batched(&g1, &cfg, &p, Some(&masks[2 * i]), batch);
            let c2 = gemm_on_array_batched(&g2, &cfg, &p, Some(&masks[2 * i + 1]), batch);
            macs += c1.counts.macs + c2.counts.macs;
            bus_words += c1.counts.bus_words + c2.counts.bus_words;
            array_cycles += c1.counts.array_busy_cycles + c2.counts.array_busy_cycles;
        }
        assert_eq!(bf.stats.ff.timing.macs as u64, macs);
        assert_eq!(bf.stats.ff.timing.total_words() as u64, bus_words);
        assert_eq!(bf.stats.ff.timing.array_cycles as u64, array_cycles);
        let live: usize = masks.iter().map(TileMask::live_count).sum();
        assert_eq!(bf.stats.ff.tiles_live, live);
    }
}
