//! Batched weight-stationary GEMM kernels — the compute core of the
//! serving runtime.
//!
//! The schedule is the same j-outer / k-inner tile grid as the
//! per-utterance kernels ([`crate::infer::gemm`]), but the inputs are a
//! flattened `[batch*m, k]` panel and the loop nest is inverted around
//! the weights: each live tile is loaded (and, for INT8, dequantized
//! through the table) **once per batch** into a packed cache-resident
//! block, then every row of every utterance streams through it before
//! the schedule moves to the next tile. That is the functional image of
//! weight-stationary reuse — programming charged once, streaming charged
//! per utterance — and the accounting matches: each live tile costs
//! [`TileTiming::batched`], i.e. one [`TileTiming::live`] pass plus
//! `batch-1` [`TileTiming::reuse`] passes (cross-checked against
//! [`crate::sysim::engine::gemm_on_array_batched`] in the tests below).
//!
//! Value-exactness is bit-level, not approximate: within a tile every
//! output element accumulates its partial products in plain k-ascending
//! order — exactly the order of the per-utterance kernels — and the
//! packed weight block holds exactly the values `w_at` would have
//! produced (same table entries for INT8). So `gemm_batched_*` over a
//! flattened batch equals running the per-utterance kernel once per
//! utterance, bitwise, on both weight formats (asserted below).

use crate::sysim::TileMask;
use crate::systolic::{ArrayConfig, Quant, TileTiming};
use crate::telemetry;

use super::super::gemm::{check_grid, Linear, QuantizedLinear, TileStats};

/// Stream every input row through the packed stationary tile:
/// `y[r, n0..n0+tn] += x[r, k0..k0+tk] * wt`, per-output-element
/// products accumulated in k-ascending order (the bit-exactness
/// contract). Rows go four at a time so each packed weight row is
/// loaded once per four input rows — the register-level face of
/// weight-stationary reuse.
#[inline]
fn stream_tile(
    x: &[f32],
    y: &mut [f32],
    rows: usize,
    k: usize,
    n: usize,
    k0: usize,
    tk: usize,
    n0: usize,
    tn: usize,
    wt: &[f32],
) {
    debug_assert_eq!(wt.len(), tk * tn);
    let mut r = 0usize;
    while r + 4 <= rows {
        let x0 = &x[r * k + k0..r * k + k0 + tk];
        let x1 = &x[(r + 1) * k + k0..(r + 1) * k + k0 + tk];
        let x2 = &x[(r + 2) * k + k0..(r + 2) * k + k0 + tk];
        let x3 = &x[(r + 3) * k + k0..(r + 3) * k + k0 + tk];
        let block = &mut y[r * n..(r + 4) * n];
        let (y0, rest) = block.split_at_mut(n);
        let (y1, rest) = rest.split_at_mut(n);
        let (y2, y3) = rest.split_at_mut(n);
        let y0 = &mut y0[n0..n0 + tn];
        let y1 = &mut y1[n0..n0 + tn];
        let y2 = &mut y2[n0..n0 + tn];
        let y3 = &mut y3[n0..n0 + tn];
        for kk in 0..tk {
            let (a0, a1, a2, a3) = (x0[kk], x1[kk], x2[kk], x3[kk]);
            let wrow = &wt[kk * tn..kk * tn + tn];
            for (cc, &wv) in wrow.iter().enumerate() {
                y0[cc] += a0 * wv;
                y1[cc] += a1 * wv;
                y2[cc] += a2 * wv;
                y3[cc] += a3 * wv;
            }
        }
        r += 4;
    }
    while r < rows {
        let xrow = &x[r * k + k0..r * k + k0 + tk];
        let yrow = &mut y[r * n + n0..r * n + n0 + tn];
        for (kk, &xv) in xrow.iter().enumerate() {
            let wrow = &wt[kk * tn..kk * tn + tn];
            for (yv, &wv) in yrow.iter_mut().zip(wrow) {
                *yv += xv * wv;
            }
        }
        r += 1;
    }
}

/// The shared batched schedule: `fill` packs one live tile's weight
/// values (monomorphized per format, so the streamed FP op sequence is
/// identical across formats), then every row streams through it.
fn gemm_batched_tiled(
    x: &[f32],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    mask: Option<&TileMask>,
    tile: usize,
    quant: Quant,
    y: &mut Vec<f32>,
    wtile: &mut Vec<f32>,
    fill: impl Fn(&mut [f32], usize, usize, usize, usize),
) -> TileStats {
    assert!(batch > 0, "batched GEMM needs at least one input block");
    let rows = batch * m;
    assert_eq!(x.len(), rows * k, "x must be (batch*m) x k");
    let (kt, nt) = check_grid(k, n, tile, mask);
    y.clear();
    y.resize(rows * n, 0.0);
    let mut stats = TileStats::default();
    if rows == 0 {
        return stats;
    }
    let cfg = ArrayConfig::square(tile, quant);
    let per_tile = TileTiming::batched(&cfg, m, batch);
    let per_skip = TileTiming::skipped_pass(&cfg, m, batch);
    for j in 0..nt {
        let n0 = j * tile;
        let tn = (n0 + tile).min(n) - n0;
        for i in 0..kt {
            if let Some(ms) = mask {
                if !ms.is_live(i, j) {
                    stats.tiles_skipped += 1;
                    stats.timing.add(&per_skip);
                    continue;
                }
            }
            let k0 = i * tile;
            let tk = (k0 + tile).min(k) - k0;
            wtile.clear();
            wtile.resize(tk * tn, 0.0);
            fill(wtile, k0, tk, n0, tn);
            stream_tile(x, y, rows, k, n, k0, tk, n0, tn, wtile);
            stats.tiles_live += 1;
            stats.timing.add(&per_tile);
        }
    }
    stats
}

/// Batched FP32 GEMM: `y[b*m, n] = x[b*m, k] * w[k, n]`, dead tiles
/// skipped, each live tile packed once per batch. `wtile` is the
/// caller-owned packing scratch (no steady-state allocation).
pub fn gemm_batched_f32(
    x: &[f32],
    w: &[f32],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    mask: Option<&TileMask>,
    tile: usize,
    y: &mut Vec<f32>,
    wtile: &mut Vec<f32>,
) -> TileStats {
    assert_eq!(w.len(), k * n, "w must be k x n");
    let mut span = telemetry::Span::begin("gemm.batched_f32");
    let stats = gemm_batched_tiled(
        x,
        batch,
        m,
        k,
        n,
        mask,
        tile,
        Quant::Fp32,
        y,
        wtile,
        |dst, k0, tk, n0, tn| {
            for kk in 0..tk {
                let row = (k0 + kk) * n + n0;
                dst[kk * tn..kk * tn + tn].copy_from_slice(&w[row..row + tn]);
            }
        },
    );
    if span.is_live() {
        span.attr("batch", batch);
        span.attr("m", m);
        stats.annotate(&mut span);
    }
    stats
}

/// Batched INT8 GEMM: the identical schedule and streaming loop, with
/// each live tile dequantized through the table(s) once per batch
/// ([`QuantizedLinear::dequant_tile`]) instead of once per MAC.
pub fn gemm_batched_int8(
    x: &[f32],
    w: &QuantizedLinear,
    batch: usize,
    m: usize,
    mask: Option<&TileMask>,
    tile: usize,
    y: &mut Vec<f32>,
    wtile: &mut Vec<f32>,
) -> TileStats {
    let mut span = telemetry::Span::begin("gemm.batched_int8");
    let stats = gemm_batched_tiled(
        x,
        batch,
        m,
        w.k,
        w.n,
        mask,
        tile,
        Quant::Int8,
        y,
        wtile,
        |dst, k0, tk, n0, tn| w.dequant_tile(dst, k0, tk, n0, tn),
    );
    if span.is_live() {
        span.attr("batch", batch);
        span.attr("m", m);
        stats.annotate(&mut span);
    }
    stats
}

impl Linear {
    /// Weight-stationary batched GEMM over `batch` blocks of `m` rows
    /// (the serving-runtime counterpart of [`Linear::gemm`]).
    pub fn gemm_batched(
        &self,
        x: &[f32],
        batch: usize,
        m: usize,
        mask: Option<&TileMask>,
        tile: usize,
        y: &mut Vec<f32>,
        wtile: &mut Vec<f32>,
    ) -> TileStats {
        match self {
            Linear::F32 { k, n, w } => {
                gemm_batched_f32(x, w, batch, m, *k, *n, mask, tile, y, wtile)
            }
            Linear::Int8(q) => gemm_batched_int8(x, q, batch, m, mask, tile, y, wtile),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::gemm::{gemm_f32, gemm_int8};
    use crate::model::{GemmKind, GemmShape};
    use crate::sysim::engine::gemm_on_array_batched;
    use crate::sysim::SimParams;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn random_mask(rng: &mut Rng, kt: usize, nt: usize, p_dead: f64) -> TileMask {
        TileMask {
            kt,
            nt,
            live: (0..kt * nt).map(|_| !rng.chance(p_dead)).collect(),
        }
    }

    /// Per-utterance reference: the PR-2 kernel run once per block,
    /// outputs concatenated, stats summed.
    fn per_utterance_f32(
        x: &[f32],
        w: &[f32],
        batch: usize,
        m: usize,
        k: usize,
        n: usize,
        mask: Option<&TileMask>,
        t: usize,
    ) -> (Vec<f32>, TileStats) {
        let mut out = Vec::with_capacity(batch * m * n);
        let mut stats = TileStats::default();
        let mut y = Vec::new();
        for u in 0..batch {
            let st = gemm_f32(&x[u * m * k..(u + 1) * m * k], w, m, k, n, mask, t, &mut y);
            stats.add(&st);
            out.extend_from_slice(&y);
        }
        (out, stats)
    }

    #[test]
    fn prop_batched_f32_bitwise_equals_per_utterance() {
        check("batched f32 == per-utterance f32", 32, |rng: &mut Rng| {
            let t = [2usize, 4, 8][rng.index(3)];
            let batch = rng.index(4) + 1;
            let m = rng.index(8) + 1;
            let k = rng.index(3 * t) + 1;
            let n = rng.index(3 * t) + 1;
            let x: Vec<f32> = (0..batch * m * k).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let mask = random_mask(rng, k.div_ceil(t), n.div_ceil(t), 0.3);
            let (want, pstats) = per_utterance_f32(&x, &w, batch, m, k, n, Some(&mask), t);
            let mut got = Vec::new();
            let mut scratch = Vec::new();
            let bstats =
                gemm_batched_f32(&x, &w, batch, m, k, n, Some(&mask), t, &mut got, &mut scratch);
            if got != want {
                return (false, format!("t={t} b={batch} m={m} k={k} n={n}"));
            }
            // Same skip schedule; weight programming charged once per
            // batch instead of once per utterance.
            let ok = bstats.tiles_live * batch == pstats.tiles_live
                && bstats.tiles_skipped * batch == pstats.tiles_skipped
                && bstats.timing.macs == pstats.timing.macs
                && bstats.timing.in_words == pstats.timing.in_words
                && bstats.timing.prog_words * batch == pstats.timing.prog_words;
            (ok, format!("stats b={batch}: {bstats:?} vs {pstats:?}"))
        });
    }

    #[test]
    fn prop_batched_int8_bitwise_equals_per_utterance() {
        check("batched int8 == per-utterance int8", 32, |rng: &mut Rng| {
            let t = [2usize, 4, 8][rng.index(3)];
            let batch = rng.index(4) + 1;
            let m = rng.index(8) + 1;
            let k = rng.index(3 * t) + 1;
            let n = rng.index(3 * t) + 1;
            let per_channel = rng.chance(0.5);
            let x: Vec<f32> = (0..batch * m * k).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let q = if per_channel {
                QuantizedLinear::from_f32_per_channel(&w, k, n)
            } else {
                QuantizedLinear::from_f32(&w, k, n)
            };
            let mask = random_mask(rng, k.div_ceil(t), n.div_ceil(t), 0.4);
            let mut want = Vec::with_capacity(batch * m * n);
            let mut y = Vec::new();
            for u in 0..batch {
                gemm_int8(&x[u * m * k..(u + 1) * m * k], &q, m, Some(&mask), t, &mut y);
                want.extend_from_slice(&y);
            }
            let mut got = Vec::new();
            let mut scratch = Vec::new();
            gemm_batched_int8(&x, &q, batch, m, Some(&mask), t, &mut got, &mut scratch);
            (
                got == want,
                format!("t={t} b={batch} m={m} k={k} n={n} pc={per_channel}"),
            )
        });
    }

    #[test]
    fn batched_timing_is_live_plus_reuse() {
        // Per live tile, the functional engine charges exactly one live
        // pass plus batch-1 reuse passes — the TileTiming::reuse model.
        let mut rng = Rng::new(51);
        let (t, batch, m, k, n) = (4usize, 3usize, 6usize, 16usize, 12usize);
        let x: Vec<f32> = (0..batch * m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mask = random_mask(&mut rng, 4, 3, 0.4);
        let mut y = Vec::new();
        let mut scratch = Vec::new();
        let stats =
            gemm_batched_f32(&x, &w, batch, m, k, n, Some(&mask), t, &mut y, &mut scratch);
        let cfg = ArrayConfig::square(t, Quant::Fp32);
        let mut want = TileTiming::skipped();
        for _ in 0..mask.live_count() {
            want.add(&TileTiming::live(&cfg, m));
            for _ in 1..batch {
                want.add(&TileTiming::reuse(&cfg, m));
            }
        }
        // Dead tiles contribute only their avoided-work occupancy.
        for _ in 0..mask.n_tiles() - mask.live_count() {
            want.add(&TileTiming::skipped_pass(&cfg, m, batch));
        }
        assert_eq!(stats.timing, want);
        assert_eq!(stats.tiles_live, mask.live_count());
    }

    #[test]
    fn batched_stats_match_analytic_batched_engine() {
        // Functional x analytic at batch scope: the schedule the batched
        // kernel executed must cost exactly what the analytic simulator
        // charges for the same GEMM + mask + batch.
        let mut rng = Rng::new(53);
        let (t, batch, m, k, n) = (8usize, 4usize, 16usize, 32usize, 24usize);
        let x: Vec<f32> = (0..batch * m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mask = random_mask(&mut rng, 4, 3, 0.5);
        let g = GemmShape { m, k, n, kind: GemmKind::FeedForward };
        let p = SimParams::default();
        for quant in [Quant::Fp32, Quant::Int8] {
            let cfg = ArrayConfig::square(t, quant);
            let cost = gemm_on_array_batched(&g, &cfg, &p, Some(&mask), batch);
            let mut y = Vec::new();
            let mut scratch = Vec::new();
            let stats = match quant {
                Quant::Fp32 => gemm_batched_f32(
                    &x, &w, batch, m, k, n, Some(&mask), t, &mut y, &mut scratch,
                ),
                Quant::Int8 => {
                    let q = QuantizedLinear::from_f32(&w, k, n);
                    gemm_batched_int8(&x, &q, batch, m, Some(&mask), t, &mut y, &mut scratch)
                }
            };
            assert_eq!(cost.counts.macs, stats.timing.macs as u64, "{quant:?}");
            assert_eq!(
                cost.counts.bus_words,
                stats.timing.total_words() as u64,
                "{quant:?}"
            );
            assert_eq!(
                cost.counts.array_busy_cycles,
                stats.timing.array_cycles as u64,
                "{quant:?}"
            );
            assert_eq!(
                cost.occ, stats.timing.occ,
                "{quant:?}: analytic occupancy must match the functional schedule"
            );
        }
    }

    #[test]
    fn linear_dispatch_and_batch_one() {
        // batch == 1 is the per-utterance kernel, bitwise, through the
        // Linear front door in both formats.
        let mut rng = Rng::new(57);
        let (t, m, k, n) = (4usize, 7, 12, 8);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mask = random_mask(&mut rng, 3, 2, 0.3);
        for lin in [
            Linear::from_f32(w.clone(), k, n),
            Linear::quantized(&w, k, n),
            Linear::quantized_per_channel(&w, k, n),
        ] {
            let mut a = Vec::new();
            let sa = lin.gemm(&x, m, Some(&mask), t, &mut a);
            let mut b = Vec::new();
            let mut scratch = Vec::new();
            let sb = lin.gemm_batched(&x, 1, m, Some(&mask), t, &mut b, &mut scratch);
            assert_eq!(a, b);
            assert_eq!(sa, sb, "batch-1 accounting degenerates to live passes");
        }
    }

    #[test]
    fn empty_rows_return_empty() {
        let w = vec![1.0f32; 16];
        let mut y = vec![9.0f32; 3];
        let mut scratch = Vec::new();
        let stats =
            gemm_batched_f32(&[], &w, 2, 0, 4, 4, None, 4, &mut y, &mut scratch);
        assert!(y.is_empty());
        assert_eq!(stats, TileStats::default());
    }
}
