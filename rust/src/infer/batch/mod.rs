//! Batched weight-stationary serving runtime — the throughput face of
//! the native engine.
//!
//! The per-utterance engine ([`crate::infer::encoder::Forward`]) runs
//! one utterance at a time and reprograms every live weight tile per
//! utterance — exactly the reuse the analytic model's
//! [`crate::systolic::TileTiming::reuse`] term says a weight-stationary
//! array should not pay. This module closes that gap for serving:
//!
//! - [`gemm`] — flattened `[batch*seq, d]` GEMM kernels (FP32 and
//!   sign-magnitude INT8) that load/dequantize each pruned weight tile
//!   **once per batch** into a packed cache-resident block and stream
//!   all utterances through it (4-row register blocking), on the same
//!   j-outer/k-inner skip schedule as the per-utterance kernels. Each
//!   live tile is charged [`crate::systolic::TileTiming::batched`] — one
//!   live pass plus `batch-1` reuse passes — and the counts cross-check
//!   exactly against [`crate::sysim::engine::gemm_on_array_batched`].
//! - [`encoder`] — [`BatchForward`], the batched encoder forward: all
//!   weight GEMMs flattened across the batch, pad-mask-aware
//!   per-utterance attention, **bitwise identical** outputs to running
//!   the per-utterance reference once per utterance (FP32 and INT8,
//!   ragged pad tails included — the value-exactness contract that lets
//!   [`crate::infer::NativeBackend`] serve batches on this path while
//!   the per-utterance engine remains the stats-exact reference).

pub mod encoder;
pub mod gemm;

pub use encoder::BatchForward;
pub use gemm::{gemm_batched_f32, gemm_batched_int8};
