//! System energy model: converts event counts from the full-system
//! simulator into joules.
//!
//! Constants are representative 28 nm / LPDDR-class figures chosen so the
//! absolute magnitudes land in the range of the paper's Table 3 (single-
//! digit joules per encoder inference at seconds-scale runtimes); every
//! reproduced *claim* is relative (speedup %, energy-saving %), so the
//! calibration affects presentation, not conclusions. The per-PE dynamic
//! energy is derived from [`super::power_mw`], keeping the §4.2 FP32/INT8
//! power relation intact by construction.

use crate::systolic::ArrayConfig;

use super::power_mw;

/// Event counts accumulated by one simulated execution
/// (produced by [`crate::sysim::System`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SysCounts {
    /// Total core cycles (1 GHz clock).
    pub core_cycles: u64,
    /// Cycles the systolic array spent computing.
    pub array_busy_cycles: u64,
    /// MAC operations executed in the array.
    pub macs: u64,
    /// 32-bit words moved over the accelerator interface.
    pub bus_words: u64,
    /// Cache events.
    pub l1i_hits: u64,
    pub l1d_hits: u64,
    pub l2_hits: u64,
    pub dram_accesses: u64,
}

impl SysCounts {
    pub fn add(&mut self, o: &SysCounts) {
        self.core_cycles += o.core_cycles;
        self.array_busy_cycles += o.array_busy_cycles;
        self.macs += o.macs;
        self.bus_words += o.bus_words;
        self.l1i_hits += o.l1i_hits;
        self.l1d_hits += o.l1d_hits;
        self.l2_hits += o.l2_hits;
        self.dram_accesses += o.dram_accesses;
    }

    /// Wall-clock seconds at the 1 GHz system clock.
    pub fn seconds(&self, clock_hz: f64) -> f64 {
        self.core_cycles as f64 / clock_hz
    }
}

/// Per-event energies (joules) + static powers (watts).
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// In-order core dynamic energy per cycle (≈150 mW @ 1 GHz).
    pub core_per_cycle_j: f64,
    /// L1 hit energy (instruction or data).
    pub l1_hit_j: f64,
    /// L2 hit energy.
    pub l2_hit_j: f64,
    /// DRAM access energy (per 64 B line).
    pub dram_access_j: f64,
    /// Accelerator interface energy per 32-bit word.
    pub bus_word_j: f64,
    /// Array leakage as a fraction of full-utilization power.
    pub array_leak_frac: f64,
    /// System clock (Hz).
    pub clock_hz: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            core_per_cycle_j: 150e-12, // 150 mW @ 1 GHz
            l1_hit_j: 15e-12,
            l2_hit_j: 80e-12,
            dram_access_j: 15e-9,
            bus_word_j: 8e-12,
            array_leak_frac: 0.08,
            clock_hz: 1e9,
        }
    }
}

impl EnergyModel {
    /// Array dynamic energy per MAC, derived from the calibrated power
    /// model: `P_full / (n_pes * clock)`.
    pub fn mac_energy_j(&self, cfg: &ArrayConfig) -> f64 {
        power_mw(cfg) * 1e-3 / (cfg.n_pes() as f64 * self.clock_hz)
    }

    /// Memory-system energy (caches + DRAM + accelerator bus).
    fn mem_j(&self, c: &SysCounts) -> f64 {
        self.bus_word_j * c.bus_words as f64
            + self.l1_hit_j * (c.l1i_hits + c.l1d_hits) as f64
            + self.l2_hit_j * c.l2_hits as f64
            + self.dram_access_j * c.dram_accesses as f64
    }

    /// Accelerator-centric energy of one execution — the Table 3 /
    /// Fig. 7 "Energy" quantity: the array is powered for the duration
    /// of the run (`P(R) * t`, §4.2's quadratic-power times the runtime,
    /// which is why larger arrays cost *more* energy despite running
    /// faster: `E ∝ R² / speedup(R) ≈ R`), plus the memory traffic the
    /// accelerated execution generates.
    pub fn energy_j(&self, cfg: &ArrayConfig, c: &SysCounts) -> f64 {
        let t = c.core_cycles as f64 / self.clock_hz;
        let array = power_mw(cfg) * 1e-3 * t;
        array + self.mem_j(c)
    }

    /// Energy of the software-only (CPU baseline) execution: core +
    /// memory, no array.
    pub fn energy_cpu_j(&self, c: &SysCounts) -> f64 {
        self.core_per_cycle_j * c.core_cycles as f64 + self.mem_j(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systolic::Quant;

    fn counts() -> SysCounts {
        SysCounts {
            core_cycles: 1_000_000,
            array_busy_cycles: 400_000,
            macs: 10_000_000,
            bus_words: 2_000_000,
            l1i_hits: 900_000,
            l1d_hits: 800_000,
            l2_hits: 50_000,
            dram_accesses: 5_000,
        }
    }

    #[test]
    fn int8_mac_energy_is_lower() {
        let m = EnergyModel::default();
        let f = m.mac_energy_j(&ArrayConfig::square(8, Quant::Fp32));
        let i = m.mac_energy_j(&ArrayConfig::square(8, Quant::Int8));
        assert!(i < f);
        assert!(((1.0 - i / f) - 0.195).abs() < 1e-9); // §4.2 power saving
    }

    #[test]
    fn mac_energy_independent_of_array_size() {
        // Per-PE energy is a device property; total power scales with n.
        let m = EnergyModel::default();
        let a = m.mac_energy_j(&ArrayConfig::square(4, Quant::Fp32));
        let b = m.mac_energy_j(&ArrayConfig::square(32, Quant::Fp32));
        assert!((a - b).abs() < 1e-18);
    }

    #[test]
    fn energy_positive_and_additive() {
        let m = EnergyModel::default();
        let cfg = ArrayConfig::square(8, Quant::Int8);
        let e1 = m.energy_j(&cfg, &counts());
        assert!(e1 > 0.0);
        let mut doubled = counts();
        doubled.add(&counts());
        let e2 = m.energy_j(&cfg, &doubled);
        assert!((e2 - 2.0 * e1).abs() / e1 < 1e-9);
    }

    #[test]
    fn shorter_runs_cost_less_energy() {
        // Array energy is power x time: halving the runtime (what SASP
        // does) halves the array term.
        let m = EnergyModel::default();
        let cfg = ArrayConfig::square(8, Quant::Fp32);
        let a = counts();
        let mut b = counts();
        b.core_cycles /= 2;
        b.bus_words /= 2;
        assert!(m.energy_j(&cfg, &b) < m.energy_j(&cfg, &a));
    }

    #[test]
    fn bigger_array_more_energy_at_sublinear_speedup() {
        // Table 3 direction: 8->32 gives ~2.5-3x speedup but 16x power,
        // so energy must rise.
        let m = EnergyModel::default();
        let c8 = counts();
        let mut c32 = counts();
        c32.core_cycles = (c8.core_cycles as f64 / 2.57) as u64;
        let e8 = m.energy_j(&ArrayConfig::square(8, Quant::Fp32), &c8);
        let e32 = m.energy_j(&ArrayConfig::square(32, Quant::Fp32), &c32);
        assert!(e32 > e8, "e8={e8:.3e} e32={e32:.3e}");
    }

    #[test]
    fn cpu_energy_has_no_array_term() {
        let m = EnergyModel::default();
        let c = counts();
        let cpu = m.energy_cpu_j(&c);
        assert!(cpu > 0.0);
        // Accelerated energy with a huge array dwarfs CPU-core energy at
        // the same cycle count.
        let acc = m.energy_j(&ArrayConfig::square(32, Quant::Fp32), &c);
        assert!(acc > cpu * 0.5);
    }

    #[test]
    fn seconds_at_clock() {
        let c = counts();
        assert!((c.seconds(1e9) - 1e-3).abs() < 1e-12);
    }
}
