//! Per-component area/power breakdown of a PE mesh instance — the §4.2
//! decomposition (multiplier vs adder+registers+skew), used by the Fig. 6
//! report and by the ablation bench on the hybrid-multiplier design.

use crate::systolic::{ArrayConfig, Quant};

use super::{
    AREA_PER_PE_FP32_MM2, INT8_AREA_SAVING, INT8_POWER_SAVING,
    MULT_AREA_FRAC_FP32, MULT_POWER_FRAC_FP32, POWER_PER_PE_FP32_MW,
};

/// Area split of one instance (mm²).
#[derive(Clone, Copy, Debug)]
pub struct AreaBreakdown {
    pub multipliers: f64,
    /// Adders, accumulation registers, dataflow registers.
    pub adders_regs: f64,
    /// Peripheral skew shift registers + control.
    pub periphery: f64,
}

impl AreaBreakdown {
    pub fn total(&self) -> f64 {
        self.multipliers + self.adders_regs + self.periphery
    }
}

/// Power split of one instance at full utilization (mW).
#[derive(Clone, Copy, Debug)]
pub struct PowerBreakdown {
    pub multipliers: f64,
    pub adders_regs: f64,
    pub periphery: f64,
}

impl PowerBreakdown {
    pub fn total(&self) -> f64 {
        self.multipliers + self.adders_regs + self.periphery
    }
}

/// Fraction of the non-multiplier budget attributed to the periphery
/// (skew registers + control). The paper does not further decompose the
/// 44.4 % remainder; 1/4 of it is a placement-typical share.
const PERIPHERY_FRAC_OF_REST: f64 = 0.25;

/// Multiplier area saving of the hybrid design, derived so the *total*
/// instance saving equals the paper's 35.3 % average (only the multiplier
/// shrinks): `0.353 / 0.556`.
pub fn hybrid_mult_area_saving() -> f64 {
    INT8_AREA_SAVING / MULT_AREA_FRAC_FP32
}

/// Multiplier power saving of the hybrid design: `0.195 / 0.336`.
pub fn hybrid_mult_power_saving() -> f64 {
    INT8_POWER_SAVING / MULT_POWER_FRAC_FP32
}

/// Area breakdown of an instance.
pub fn area_breakdown(cfg: &ArrayConfig) -> AreaBreakdown {
    let n = cfg.n_pes() as f64;
    let fp32_total = AREA_PER_PE_FP32_MM2 * n;
    let mult_fp32 = fp32_total * MULT_AREA_FRAC_FP32;
    let rest = fp32_total * (1.0 - MULT_AREA_FRAC_FP32);
    let mult = match cfg.quant {
        Quant::Fp32 => mult_fp32,
        Quant::Int8 => mult_fp32 * (1.0 - hybrid_mult_area_saving()),
    };
    AreaBreakdown {
        multipliers: mult,
        adders_regs: rest * (1.0 - PERIPHERY_FRAC_OF_REST),
        periphery: rest * PERIPHERY_FRAC_OF_REST,
    }
}

/// Power breakdown of an instance at full utilization.
pub fn power_breakdown(cfg: &ArrayConfig) -> PowerBreakdown {
    let n = cfg.n_pes() as f64;
    let fp32_total = POWER_PER_PE_FP32_MW * n;
    let mult_fp32 = fp32_total * MULT_POWER_FRAC_FP32;
    let rest = fp32_total * (1.0 - MULT_POWER_FRAC_FP32);
    let mult = match cfg.quant {
        Quant::Fp32 => mult_fp32,
        Quant::Int8 => mult_fp32 * (1.0 - hybrid_mult_power_saving()),
    };
    PowerBreakdown {
        multipliers: mult,
        adders_regs: rest * (1.0 - PERIPHERY_FRAC_OF_REST),
        periphery: rest * PERIPHERY_FRAC_OF_REST,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwmodel::{area_mm2, power_mw};

    #[test]
    fn breakdown_sums_to_total() {
        for q in [Quant::Fp32, Quant::Int8] {
            for n in [4, 8, 16, 32] {
                let cfg = ArrayConfig::square(n, q);
                let a = area_breakdown(&cfg);
                assert!((a.total() - area_mm2(&cfg)).abs() < 1e-12,
                        "area {n} {q:?}");
                let p = power_breakdown(&cfg);
                assert!((p.total() - power_mw(&cfg)).abs() < 1e-9,
                        "power {n} {q:?}");
            }
        }
    }

    #[test]
    fn fp32_mult_share_matches_paper() {
        let cfg = ArrayConfig::square(8, Quant::Fp32);
        let a = area_breakdown(&cfg);
        assert!((a.multipliers / a.total() - 0.556).abs() < 1e-9);
        let p = power_breakdown(&cfg);
        assert!((p.multipliers / p.total() - 0.336).abs() < 1e-9);
    }

    #[test]
    fn hybrid_multiplier_is_smaller() {
        let f = area_breakdown(&ArrayConfig::square(8, Quant::Fp32));
        let i = area_breakdown(&ArrayConfig::square(8, Quant::Int8));
        assert!(i.multipliers < f.multipliers);
        // Non-multiplier logic is unchanged by quantization.
        assert!((i.adders_regs - f.adders_regs).abs() < 1e-12);
        assert!((i.periphery - f.periphery).abs() < 1e-12);
    }

    #[test]
    fn derived_mult_savings_are_physical() {
        // Must be in (0, 1): the hybrid multiplier shrinks but exists.
        let a = hybrid_mult_area_saving();
        let p = hybrid_mult_power_saving();
        assert!(a > 0.0 && a < 1.0, "area saving {a}");
        assert!(p > 0.0 && p < 1.0, "power saving {p}");
    }
}
