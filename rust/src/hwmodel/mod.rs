//! Synthesis-calibrated hardware cost model (the paper's tier 3).
//!
//! The paper synthesizes the systolic-array template in TSMC 28 nm at
//! 1 GHz and reports area/power per instance (Fig. 6, Table 3) plus the
//! component breakdown of §4.2. With no synthesis flow available here,
//! this module is an *analytical* model **calibrated to the paper's own
//! published numbers**:
//!
//! - total FP32 area `= α_f · R²` with `α_f = 0.21 mm² / 64 PEs`
//!   (Table 3: 4→0.05, 8→0.21, 16→0.83, 32→3.34 mm²; quadratic per §4.2);
//! - multiplier share of the FP32 PE: 55.6 % area / 33.6 % power (§4.2);
//! - hybrid FP32_INT8 instances save 35.3 % area / 19.5 % power on
//!   average (§4.2; Table 3 INT8 areas 0.03/0.14/0.53/2.13 mm²).
//!
//! Everything downstream (Fig. 6, Fig. 10 area-energy product, Table 3)
//! consumes these functions, so the model is the single calibration
//! point.

pub mod components;
pub mod energy;

pub use components::{AreaBreakdown, PowerBreakdown};
pub use energy::{EnergyModel, SysCounts};

use crate::systolic::{ArrayConfig, Quant};

/// FP32 area per PE slot (mm², includes its share of skew registers and
/// control): Table 3 gives 0.21 mm² for the 8×8 FP32 instance.
pub const AREA_PER_PE_FP32_MM2: f64 = 0.21 / 64.0;

/// §4.2: the multiplier is 55.6 % of FP32 instance area.
pub const MULT_AREA_FRAC_FP32: f64 = 0.556;

/// §4.2: average area saving of the hybrid FP32_INT8 instance.
pub const INT8_AREA_SAVING: f64 = 0.353;

/// Dynamic power per FP32 PE at 1 GHz full utilization (mW). Fig. 6 has
/// no numeric labels in the text; 30 mW for the 8×8 FP32 instance is a
/// representative 28 nm figure and only *relative* power enters any
/// reproduced plot (the paper's own claims are all relative).
pub const POWER_PER_PE_FP32_MW: f64 = 30.0 / 64.0;

/// §4.2: the multiplier is 33.6 % of FP32 instance power.
pub const MULT_POWER_FRAC_FP32: f64 = 0.336;

/// §4.2: average power saving of the hybrid FP32_INT8 instance.
pub const INT8_POWER_SAVING: f64 = 0.195;

/// Synthesized area of an array instance (mm², TSMC 28 nm @ 1 GHz).
pub fn area_mm2(cfg: &ArrayConfig) -> f64 {
    let per_pe = match cfg.quant {
        Quant::Fp32 => AREA_PER_PE_FP32_MM2,
        Quant::Int8 => AREA_PER_PE_FP32_MM2 * (1.0 - INT8_AREA_SAVING),
    };
    per_pe * cfg.n_pes() as f64
}

/// Power at full utilization (mW).
pub fn power_mw(cfg: &ArrayConfig) -> f64 {
    let per_pe = match cfg.quant {
        Quant::Fp32 => POWER_PER_PE_FP32_MW,
        Quant::Int8 => POWER_PER_PE_FP32_MW * (1.0 - INT8_POWER_SAVING),
    };
    per_pe * cfg.n_pes() as f64
}

/// Area–energy product figure of merit used by Fig. 10 (mm² · J).
pub fn area_energy_product(cfg: &ArrayConfig, energy_j: f64) -> f64 {
    area_mm2(cfg) * energy_j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sq(n: usize, q: Quant) -> ArrayConfig {
        ArrayConfig::square(n, q)
    }

    #[test]
    fn fp32_areas_match_table3() {
        // Paper Table 3: 0.05 / 0.21 / 0.83 / 3.34 mm².
        let paper = [(4, 0.05), (8, 0.21), (16, 0.83), (32, 3.34)];
        for (n, want) in paper {
            let got = area_mm2(&sq(n, Quant::Fp32));
            let rel = (got - want).abs() / want;
            assert!(rel < 0.06, "size {n}: got {got:.3} want {want}");
        }
    }

    #[test]
    fn int8_areas_match_table3() {
        // Paper Table 3: 0.03 / 0.14 / 0.53 / 2.13 mm².
        let paper = [(4, 0.03), (8, 0.14), (16, 0.53), (32, 2.13)];
        for (n, want) in paper {
            let got = area_mm2(&sq(n, Quant::Int8));
            let rel = (got - want).abs() / want;
            assert!(rel < 0.15, "size {n}: got {got:.3} want {want}");
        }
    }

    #[test]
    fn quadratic_scaling_between_sizes() {
        // §4.2: ~4x between 4x4 and 8x8.
        let r = area_mm2(&sq(8, Quant::Fp32)) / area_mm2(&sq(4, Quant::Fp32));
        assert!((r - 4.0).abs() < 1e-9);
        let p = power_mw(&sq(16, Quant::Int8)) / power_mw(&sq(8, Quant::Int8));
        assert!((p - 4.0).abs() < 1e-9);
    }

    #[test]
    fn int8_savings_match_section_4_2() {
        let a = 1.0 - area_mm2(&sq(8, Quant::Int8)) / area_mm2(&sq(8, Quant::Fp32));
        assert!((a - INT8_AREA_SAVING).abs() < 1e-9);
        let p = 1.0 - power_mw(&sq(8, Quant::Int8)) / power_mw(&sq(8, Quant::Fp32));
        assert!((p - INT8_POWER_SAVING).abs() < 1e-9);
    }

    #[test]
    fn area_energy_product_monotone_in_both() {
        let small = area_energy_product(&sq(8, Quant::Int8), 2.0);
        let bigger_array = area_energy_product(&sq(16, Quant::Int8), 2.0);
        let more_energy = area_energy_product(&sq(8, Quant::Int8), 3.0);
        assert!(bigger_array > small && more_energy > small);
    }
}
