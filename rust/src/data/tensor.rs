//! A small dense tensor: shape + dtype + contiguous little-endian buffer.
//!
//! This is deliberately not an ndarray library — the coordinator only
//! needs typed views, shape bookkeeping, and conversion to/from PJRT
//! literals (done in [`crate::runtime`]).

use anyhow::{bail, Result};

/// Element type — mirrors the codes in `python/compile/tensorio.py`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    I8,
}

impl DType {
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 => 1,
        }
    }

    pub fn code(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I32 => 1,
            DType::I8 => 2,
        }
    }

    pub fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::I8,
            _ => bail!("unknown dtype code {c}"),
        })
    }

    /// Parse numpy dtype names used in the AOT manifests.
    pub fn from_name(name: &str) -> Result<Self> {
        Ok(match name {
            "float32" => DType::F32,
            "int32" => DType::I32,
            "int8" => DType::I8,
            _ => bail!("unknown dtype name {name}"),
        })
    }
}

/// Dense tensor in C (row-major) order.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub dtype: DType,
    /// Raw little-endian bytes, `len == numel * dtype.size()`.
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(
            // scalar (rank 0) has one element
            if self.shape.is_empty() { 1 } else { 0 },
        )
    }

    pub fn zeros(shape: &[usize], dtype: DType) -> Tensor {
        let numel: usize = shape.iter().product::<usize>().max(
            if shape.is_empty() { 1 } else { 0 },
        );
        Tensor {
            shape: shape.to_vec(),
            dtype,
            data: vec![0u8; numel * dtype.size()],
        }
    }

    pub fn from_f32(shape: &[usize], values: &[f32]) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>().max(if shape.is_empty() { 1 } else { 0 }),
            values.len(),
            "shape/value mismatch"
        );
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { shape: shape.to_vec(), dtype: DType::F32, data }
    }

    pub fn from_i32(shape: &[usize], values: &[i32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { shape: shape.to_vec(), dtype: DType::I32, data }
    }

    pub fn from_i8(shape: &[usize], values: &[i8]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        Tensor {
            shape: shape.to_vec(),
            dtype: DType::I8,
            data: values.iter().map(|v| *v as u8).collect(),
        }
    }

    // -- typed views ------------------------------------------------------

    pub fn f32s(&self) -> Vec<f32> {
        assert_eq!(self.dtype, DType::F32);
        self.data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    pub fn i32s(&self) -> Vec<i32> {
        assert_eq!(self.dtype, DType::I32);
        self.data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    pub fn i8s(&self) -> Vec<i8> {
        assert_eq!(self.dtype, DType::I8);
        self.data.iter().map(|b| *b as i8).collect()
    }

    /// In-place f32 mutation via a closure over (flat index, value).
    pub fn map_f32_inplace(&mut self, mut f: impl FnMut(usize, f32) -> f32) {
        assert_eq!(self.dtype, DType::F32);
        for (i, chunk) in self.data.chunks_exact_mut(4).enumerate() {
            let v = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            chunk.copy_from_slice(&f(i, v).to_le_bytes());
        }
    }

    /// Row-major 2D accessor helper (debug / tests).
    pub fn at2_f32(&self, r: usize, c: usize) -> f32 {
        assert_eq!(self.shape.len(), 2);
        let cols = self.shape[1];
        let idx = (r * cols + c) * 4;
        f32::from_le_bytes([
            self.data[idx],
            self.data[idx + 1],
            self.data[idx + 2],
            self.data[idx + 3],
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let t = Tensor::from_f32(&[2, 3], &[1.0, -2.5, 3.0, 0.0, 5.5, -6.0]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.f32s(), vec![1.0, -2.5, 3.0, 0.0, 5.5, -6.0]);
        assert_eq!(t.at2_f32(1, 1), 5.5);
    }

    #[test]
    fn i8_roundtrip() {
        let t = Tensor::from_i8(&[4], &[-128, -1, 0, 127]);
        assert_eq!(t.i8s(), vec![-128, -1, 0, 127]);
    }

    #[test]
    fn scalar_tensor() {
        let t = Tensor::from_f32(&[], &[2.25]);
        assert_eq!(t.numel(), 1);
        assert_eq!(t.f32s(), vec![2.25]);
    }

    #[test]
    fn map_inplace() {
        let mut t = Tensor::from_f32(&[3], &[1.0, 2.0, 3.0]);
        t.map_f32_inplace(|i, v| v * i as f32);
        assert_eq!(t.f32s(), vec![0.0, 2.0, 6.0]);
    }

    #[test]
    fn dtype_name_parse() {
        assert_eq!(DType::from_name("float32").unwrap(), DType::F32);
        assert_eq!(DType::from_name("int8").unwrap(), DType::I8);
        assert!(DType::from_name("float64").is_err());
    }
}
