//! Reader/writer for the `SASPTNS1` tensor-bundle format
//! (see `python/compile/tensorio.py` for the authoritative layout).
//!
//! Order-preserving: the python writer iterates dict insertion order and
//! the rust side keeps a `Vec` of (name, tensor) so AOT argument order is
//! reproducible.

use std::fs;
use std::io::{Cursor, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::tensor::{DType, Tensor};

const MAGIC: &[u8; 8] = b"SASPTNS1";

/// An ordered collection of named tensors.
#[derive(Clone, Debug, Default)]
pub struct Bundle {
    pub entries: Vec<(String, Tensor)>,
}

impl Bundle {
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        self.entries
            .iter_mut()
            .find(|(n, _)| n == name)
            .map(|(_, t)| t)
    }

    pub fn require(&self, name: &str) -> Result<&Tensor> {
        self.get(name)
            .with_context(|| format!("bundle missing tensor '{name}'"))
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        if let Some(slot) = self.get_mut(name) {
            *slot = t;
        } else {
            self.entries.push((name.to_string(), t));
        }
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|(n, _)| n.as_str()).collect()
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u8(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

/// Parse a bundle from bytes.
pub fn parse_bundle(bytes: &[u8]) -> Result<Bundle> {
    let mut r = Cursor::new(bytes);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad magic: {:?}", magic);
    }
    let count = read_u32(&mut r)?;
    let mut entries = Vec::with_capacity(count as usize);
    for _ in 0..count {
        let nlen = read_u32(&mut r)? as usize;
        let mut nbuf = vec![0u8; nlen];
        r.read_exact(&mut nbuf)?;
        let name = String::from_utf8(nbuf).context("tensor name not utf-8")?;
        let dtype = DType::from_code(read_u8(&mut r)?)?;
        let ndim = read_u32(&mut r)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut r)? as usize);
        }
        let numel: usize = shape.iter().product::<usize>().max(
            if shape.is_empty() { 1 } else { 0 },
        );
        let mut data = vec![0u8; numel * dtype.size()];
        r.read_exact(&mut data)
            .with_context(|| format!("truncated data for '{name}'"))?;
        entries.push((name, Tensor { shape, dtype, data }));
    }
    Ok(Bundle { entries })
}

/// Load a bundle from disk.
pub fn load_bundle(path: impl AsRef<Path>) -> Result<Bundle> {
    let path = path.as_ref();
    let bytes = fs::read(path)
        .with_context(|| format!("reading bundle {}", path.display()))?;
    parse_bundle(&bytes).with_context(|| format!("parsing {}", path.display()))
}

/// Serialize a bundle to bytes.
pub fn emit_bundle(bundle: &Bundle) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(bundle.entries.len() as u32).to_le_bytes());
    for (name, t) in &bundle.entries {
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.push(t.dtype.code());
        out.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
        for d in &t.shape {
            out.extend_from_slice(&(*d as u32).to_le_bytes());
        }
        out.extend_from_slice(&t.data);
    }
    out
}

/// Write a bundle to disk.
pub fn save_bundle(path: impl AsRef<Path>, bundle: &Bundle) -> Result<()> {
    let mut f = fs::File::create(path.as_ref())?;
    f.write_all(&emit_bundle(bundle))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn sample_bundle() -> Bundle {
        let mut b = Bundle::default();
        b.insert("a", Tensor::from_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]));
        b.insert("b", Tensor::from_i32(&[3], &[-1, 0, 7]));
        b.insert("c", Tensor::from_i8(&[2], &[-128, 127]));
        b
    }

    #[test]
    fn roundtrip_in_memory() {
        let b = sample_bundle();
        let parsed = parse_bundle(&emit_bundle(&b)).unwrap();
        assert_eq!(parsed.names(), b.names());
        assert_eq!(parsed.get("a"), b.get("a"));
        assert_eq!(parsed.get("c"), b.get("c"));
    }

    #[test]
    fn roundtrip_on_disk() {
        let dir = std::env::temp_dir().join("sasp_tensorfile_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let b = sample_bundle();
        save_bundle(&path, &b).unwrap();
        let loaded = load_bundle(&path).unwrap();
        assert_eq!(loaded.get("b").unwrap().i32s(), vec![-1, 0, 7]);
    }

    #[test]
    fn bad_magic_rejected() {
        assert!(parse_bundle(b"NOTMAGIC\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn truncated_rejected() {
        let bytes = emit_bundle(&sample_bundle());
        assert!(parse_bundle(&bytes[..bytes.len() - 3]).is_err());
    }

    #[test]
    fn insert_replaces() {
        let mut b = sample_bundle();
        b.insert("a", Tensor::from_f32(&[1], &[9.0]));
        assert_eq!(b.get("a").unwrap().f32s(), vec![9.0]);
        assert_eq!(b.entries.len(), 3);
    }

    #[test]
    fn prop_roundtrip_random_bundles() {
        check("tensorfile roundtrip", 32, |rng: &mut Rng| {
            let n = rng.index(5);
            let mut b = Bundle::default();
            for i in 0..n {
                let ndim = rng.index(3);
                let shape: Vec<usize> =
                    (0..ndim).map(|_| rng.index(4) + 1).collect();
                let numel: usize = shape.iter().product::<usize>().max(
                    if shape.is_empty() { 1 } else { 0 },
                );
                let vals: Vec<f32> =
                    (0..numel).map(|_| rng.normal() as f32).collect();
                b.insert(&format!("t{i}"), Tensor::from_f32(&shape, &vals));
            }
            let rt = parse_bundle(&emit_bundle(&b)).unwrap();
            let ok = rt.entries == b.entries;
            (ok, format!("n={n}"))
        });
    }
}
