//! Data plumbing: dense tensors, the python<->rust tensor-bundle format,
//! and synthetic workload helpers shared with the python side.

pub mod tensor;
pub mod tensorfile;

pub use tensor::{DType, Tensor};
pub use tensorfile::{load_bundle, save_bundle, Bundle};
