//! Post-training weight quantization (§3.1): FP32 → INT8 with a
//! per-tensor symmetric scale, sign-and-magnitude representation
//! (matching the hybrid multiplier of §3.3 and the python oracle
//! `quantize_ref`).

use crate::arith::SignMag8;
use crate::data::Tensor;

/// Result of quantizing one weight tensor.
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    /// Quantized values in value-equivalent i8 (range -127..=127).
    pub values: Vec<i8>,
    pub shape: Vec<usize>,
    /// Dequantization scale: `w ≈ q * scale`.
    pub scale: f32,
}

/// Per-tensor symmetric quantization: `scale = max|w| / 127`,
/// `q = clamp(round_ties_even(w / scale), -127, 127)`.
///
/// Non-finite weights are sanitized rather than allowed to poison the
/// per-tensor scale: `amax` ranges over finite values only (a single
/// NaN/inf would otherwise produce a NaN/inf scale and garbage for the
/// whole tensor), NaN quantizes to 0, and ±inf saturates to ±127.
pub fn quantize(w: &Tensor) -> QuantizedTensor {
    let vals = w.f32s();
    let amax = vals
        .iter()
        .filter(|v| v.is_finite())
        .fold(0.0f32, |a, v| a.max(v.abs()));
    let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
    let values = vals
        .iter()
        .map(|v| {
            if v.is_nan() {
                0
            } else {
                // ±inf / scale stays ±inf and clamps to ±127.
                (v / scale).round_ties_even().clamp(-127.0, 127.0) as i8
            }
        })
        .collect();
    QuantizedTensor { values, shape: w.shape.clone(), scale }
}

/// Dequantize back to f32 (the numerics the FP32 artifact sees when the
/// coordinator runs a weight-quantized QoS evaluation — "fake quant",
/// value-identical to dequantizing inside the kernel).
pub fn dequantize(q: &QuantizedTensor) -> Tensor {
    let vals: Vec<f32> = q.values.iter().map(|v| *v as f32 * q.scale).collect();
    Tensor::from_f32(&q.shape, &vals)
}

/// Fake-quantize in place: `w <- dequant(quant(w))`.
pub fn fake_quantize(w: &mut Tensor) -> f32 {
    let q = quantize(w);
    *w = dequantize(&q);
    q.scale
}

impl QuantizedTensor {
    /// View values as sign-magnitude (what `SA_PROG` actually ships).
    pub fn sign_mag(&self) -> Vec<SignMag8> {
        self.values.iter().map(|v| SignMag8::from_i8(*v)).collect()
    }
}

/// Result of quantizing a 2-D `[k, n]` weight matrix with one symmetric
/// scale per **output channel** (column) — the finer-grained PTQ that
/// keeps a single outlier channel from stretching the whole tensor's
/// grid. Same sign-magnitude value domain as [`QuantizedTensor`].
#[derive(Clone, Debug)]
pub struct ChannelQuantized {
    /// Row-major `k x n` quantized values (range -127..=127).
    pub values: Vec<i8>,
    pub k: usize,
    pub n: usize,
    /// One dequantization scale per column: `w[:, c] ≈ q * scales[c]`.
    pub scales: Vec<f32>,
}

/// The per-column scale under the same sanitization rules as
/// [`quantize`]: finite-only amax, unit scale for all-zero columns.
fn column_scale(col: impl Iterator<Item = f32>) -> f32 {
    let amax = col
        .filter(|v| v.is_finite())
        .fold(0.0f32, |a, v| a.max(v.abs()));
    if amax > 0.0 {
        amax / 127.0
    } else {
        1.0
    }
}

/// Per-output-channel symmetric quantization of a 2-D `[k, n]` weight:
/// `scales[c] = max|w[:, c]| / 127`, values quantized exactly as
/// [`quantize`] does (round-ties-even, NaN→0, ±inf saturates).
pub fn quantize_per_channel(w: &Tensor) -> ChannelQuantized {
    assert_eq!(w.shape.len(), 2, "per-channel quantization needs [k, n]");
    let (k, n) = (w.shape[0], w.shape[1]);
    let vals = w.f32s();
    let scales: Vec<f32> = (0..n)
        .map(|c| column_scale((0..k).map(|r| vals[r * n + c])))
        .collect();
    let values = vals
        .iter()
        .enumerate()
        .map(|(i, v)| {
            if v.is_nan() {
                0
            } else {
                (v / scales[i % n]).round_ties_even().clamp(-127.0, 127.0) as i8
            }
        })
        .collect();
    ChannelQuantized { values, k, n, scales }
}

/// Dequantize a per-channel matrix back to f32 (the fake-quant numerics
/// — value-identical to dequantizing inside the kernel column by
/// column).
pub fn dequantize_per_channel(q: &ChannelQuantized) -> Tensor {
    let vals: Vec<f32> = q
        .values
        .iter()
        .enumerate()
        .map(|(i, v)| *v as f32 * q.scales[i % q.n])
        .collect();
    Tensor::from_f32(&[q.k, q.n], &vals)
}

/// Fake-quantize a 2-D weight in place with per-channel scales; returns
/// the per-column scales.
pub fn fake_quantize_per_channel(w: &mut Tensor) -> Vec<f32> {
    let q = quantize_per_channel(w);
    *w = dequantize_per_channel(&q);
    q.scales
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn quantize_known_values() {
        let w = Tensor::from_f32(&[4], &[0.0, 1.27, -1.27, 0.635]);
        let q = quantize(&w);
        assert!((q.scale - 0.01).abs() < 1e-6);
        assert_eq!(q.values, vec![0, 127, -127, 64]); // 63.5 rounds to even
    }

    #[test]
    fn non_finite_weights_sanitized() {
        // NaN/inf must not poison the scale: the finite values still
        // quantize exactly as they would alone.
        let w = Tensor::from_f32(
            &[5],
            &[f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1.27, -0.635],
        );
        let q = quantize(&w);
        assert!((q.scale - 0.01).abs() < 1e-6, "scale {}", q.scale);
        assert_eq!(q.values, vec![0, 127, -127, 127, -64]);
        let dq = dequantize(&q).f32s();
        assert!(dq.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn all_non_finite_tensor_gets_unit_scale() {
        let w = Tensor::from_f32(&[2], &[f32::NAN, f32::INFINITY]);
        let q = quantize(&w);
        assert_eq!(q.scale, 1.0);
        assert_eq!(q.values, vec![0, 127]);
    }

    #[test]
    fn all_zero_tensor() {
        let w = Tensor::from_f32(&[3], &[0.0; 3]);
        let q = quantize(&w);
        assert_eq!(q.scale, 1.0);
        assert!(q.values.iter().all(|v| *v == 0));
    }

    #[test]
    fn prop_roundtrip_error_half_scale() {
        check("PTQ roundtrip |err| <= scale/2", 64, |rng: &mut Rng| {
            let n = rng.index(64) + 1;
            let scale_pow = rng.index(7) as i32 - 3;
            let vals: Vec<f32> = (0..n)
                .map(|_| (rng.normal() as f32) * 10f32.powi(scale_pow))
                .collect();
            let w = Tensor::from_f32(&[n], &vals);
            let q = quantize(&w);
            let dq = dequantize(&q).f32s();
            for (a, b) in vals.iter().zip(&dq) {
                if (a - b).abs() > q.scale / 2.0 + 1e-7 {
                    return (false, format!("a={a} b={b} scale={}", q.scale));
                }
            }
            (true, String::new())
        });
    }

    #[test]
    fn prop_zero_preserved() {
        // Pruned (zero) tiles stay exactly zero through PTQ — required
        // for SASP+quant composition.
        check("quant preserves zeros", 32, |rng: &mut Rng| {
            let vals: Vec<f32> = (0..32)
                .map(|i| if i % 3 == 0 { 0.0 } else { rng.normal() as f32 })
                .collect();
            let mut w = Tensor::from_f32(&[32], &vals);
            fake_quantize(&mut w);
            let out = w.f32s();
            for (i, v) in vals.iter().enumerate() {
                if *v == 0.0 && out[i] != 0.0 {
                    return (false, format!("idx {i}"));
                }
            }
            (true, String::new())
        });
    }

    #[test]
    fn per_channel_scales_are_column_amax() {
        let w = Tensor::from_f32(
            &[2, 3],
            &[1.27, 0.5, 0.0, -0.635, 0.25, 0.0],
        );
        let q = quantize_per_channel(&w);
        assert!((q.scales[0] - 0.01).abs() < 1e-6);
        assert!((q.scales[1] - 0.5 / 127.0).abs() < 1e-8);
        assert_eq!(q.scales[2], 1.0, "all-zero column gets unit scale");
        assert_eq!(q.values, vec![127, 127, 0, -64, 64, 0]); // 63.5 -> 64
    }

    #[test]
    fn per_channel_roundtrip_tighter_than_per_tensor() {
        // The column grid is never coarser than the tensor grid, so the
        // total roundtrip error shrinks (the QoS-tightening claim at the
        // weight level). One column carries a large outlier to make the
        // per-tensor scale visibly coarse.
        let mut rng = Rng::new(11);
        let (k, n) = (32usize, 16usize);
        let mut vals: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        for r in 0..k {
            vals[r * n] *= 50.0;
        }
        let w = Tensor::from_f32(&[k, n], &vals);
        let pt = dequantize(&quantize(&w)).f32s();
        let pc = dequantize_per_channel(&quantize_per_channel(&w)).f32s();
        let sq = |dq: &[f32]| -> f64 {
            vals.iter()
                .zip(dq)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum()
        };
        let (err_pt, err_pc) = (sq(&pt), sq(&pc));
        assert!(err_pc < err_pt, "per-channel {err_pc} vs per-tensor {err_pt}");
        // And per column, the error bound is the column's own half-step.
        let q = quantize_per_channel(&w);
        for (i, (a, b)) in vals.iter().zip(&pc).enumerate() {
            assert!(
                (a - b).abs() <= q.scales[i % n] / 2.0 + 1e-7,
                "elem {i}: {a} vs {b}"
            );
        }
    }

    #[test]
    fn per_channel_preserves_zeros_and_sanitizes() {
        let w = Tensor::from_f32(
            &[2, 2],
            &[f32::NAN, 0.0, f32::INFINITY, 1.0],
        );
        let q = quantize_per_channel(&w);
        // Column 0: NaN/inf ignored for the scale -> no finite nonzero
        // values -> unit scale; NaN -> 0, inf saturates.
        assert_eq!(q.scales[0], 1.0);
        assert_eq!(q.values, vec![0, 0, 127, 127]);
        let dq = dequantize_per_channel(&q).f32s();
        assert!(dq.iter().all(|v| v.is_finite()));
        assert_eq!(dq[1], 0.0, "exact zero survives per-channel PTQ");
    }

    #[test]
    fn sign_mag_view_consistent() {
        let w = Tensor::from_f32(&[2], &[1.0, -1.0]);
        let q = quantize(&w);
        let sm = q.sign_mag();
        assert_eq!(sm[0].to_i8(), 127);
        assert_eq!(sm[1].to_i8(), -127);
    }
}
