//! Stub of the PJRT bindings the runtime layer programs against.
//!
//! The vendor set has no `xla_extension` build, so this crate provides
//! the same API surface with two behaviours:
//!
//! - [`Literal`] is a **real** container (shape + element type + bytes)
//!   — tensor<->literal conversion and everything that only shuffles
//!   data works, and is unit-tested in the sasp crate.
//! - Client / compilation / execution calls return a descriptive
//!   [`Error`] — every PJRT-dependent path in sasp is artifact-gated, so
//!   tests and benches skip cleanly instead of hitting these.
//!
//! Swapping in a real `xla` crate (see `rust/Cargo.toml`) restores full
//! PJRT execution without touching sasp code.

use std::fmt;

/// Stub error type (std error, so it flows into `anyhow::Error`).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} requires the real xla crate (PJRT is stubbed in this build; \
         see rust/Cargo.toml)"
    )))
}

/// Element types used by the sasp artifacts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    S8,
}

impl ElementType {
    pub fn size_in_bytes(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
            ElementType::S8 => 1,
        }
    }
}

/// Rust scalar types a [`Literal`] can be viewed as.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le_bytes(b: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le_bytes(b: &[u8]) -> Self {
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le_bytes(b: &[u8]) -> Self {
        i32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for i8 {
    const TY: ElementType = ElementType::S8;
    fn from_le_bytes(b: &[u8]) -> Self {
        b[0] as i8
    }
}

/// A dense host literal: element type + shape + little-endian bytes.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let numel: usize = shape.iter().product::<usize>().max(
            if shape.is_empty() { 1 } else { 0 },
        );
        if numel * ty.size_in_bytes() != data.len() {
            return Err(Error(format!(
                "literal data length {} != shape {:?} x {} bytes",
                data.len(),
                shape,
                ty.size_in_bytes()
            )));
        }
        Ok(Literal { ty, shape: shape.to_vec(), data: data.to_vec() })
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn raw_bytes(&self) -> &[u8] {
        &self.data
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        let sz = self.ty.size_in_bytes();
        Ok(self.data.chunks_exact(sz).map(T::from_le_bytes).collect())
    }

    /// Unwrap a 1-tuple result literal (identity in the stub — tuples
    /// only arise from real PJRT execution).
    pub fn to_tuple1(self) -> Result<Literal> {
        Ok(self)
    }
}

/// Parsed HLO module text (the stub keeps the text verbatim).
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// A computation ready to compile.
pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _text: proto.text.clone() }
    }
}

/// Stub PJRT client: constructible (so engine setup and `sasp info`
/// work), but compilation is unavailable.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu (PJRT unavailable; link the real xla crate)".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling an HLO module")
    }
}

/// Stub compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing a compiled module")
    }
}

/// Stub device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("fetching a device buffer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let vals = [1.5f32, -2.0, 0.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[3],
            &bytes,
        )
        .unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn length_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::S32,
            &[2],
            &[0u8; 4]
        )
        .is_err());
    }

    #[test]
    fn execution_unavailable() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let comp = XlaComputation::from_proto(&HloModuleProto { text: String::new() });
        assert!(client.compile(&comp).is_err());
    }
}
