//! Vendored minimal stand-in for the `anyhow` crate (the build is fully
//! offline — no crates.io). Implements exactly the API surface the sasp
//! crate uses: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] /
//! [`ensure!`] macros, and [`Context`] on `Result` and `Option`.
//!
//! Mirrors anyhow's structure (context via a private extension trait
//! implemented both for `Error` and blanket for std errors) so the
//! coherence story is identical to the real crate.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: a root cause (message or boxed std error) plus a
/// stack of human-readable context frames, outermost first.
pub struct Error {
    context: Vec<String>,
    root: Root,
}

enum Root {
    Msg(String),
    Source(Box<dyn StdError + Send + Sync + 'static>),
}

impl Error {
    /// Create from a display-able message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { context: Vec::new(), root: Root::Msg(message.to_string()) }
    }

    /// Wrap a std error as the root cause.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { context: Vec::new(), root: Root::Source(Box::new(error)) }
    }

    /// Attach an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.context.insert(0, context.to_string());
        self
    }

    fn frames(&self) -> Vec<String> {
        let mut out = self.context.clone();
        match &self.root {
            Root::Msg(m) => out.push(m.clone()),
            Root::Source(e) => {
                let mut cur: Option<&(dyn StdError + 'static)> = Some(e.as_ref());
                while let Some(err) = cur {
                    out.push(err.to_string());
                    cur = err.source();
                }
            }
        }
        out
    }
}

impl fmt::Display for Error {
    /// The outermost description only (context chain goes to `Debug`).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let frames = self.frames();
        write!(f, "{}", frames.first().map(String::as_str).unwrap_or("unknown error"))
    }
}

impl fmt::Debug for Error {
    /// The full chain, anyhow-style: outermost line, then "Caused by".
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let frames = self.frames();
        write!(f, "{}", frames.first().map(String::as_str).unwrap_or("unknown error"))?;
        if frames.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &frames[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error` —
// that is what makes the blanket `From` impl below coherent, exactly as
// in the real anyhow.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Private extension trait so a single blanket `Context` impl can serve
/// both `Result<T, Error>` and `Result<T, impl std::error::Error>`.
pub trait ChainableError {
    fn ext_context(self, context: String) -> Error;
}

impl ChainableError for Error {
    fn ext_context(self, context: String) -> Error {
        self.context(context)
    }
}

impl<E: StdError + Send + Sync + 'static> ChainableError for E {
    fn ext_context(self, context: String) -> Error {
        Error::new(self).context(context)
    }
}

/// Attach context to errors (and convert `Option` to `Result`).
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ChainableError> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.ext_context(f().to_string()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn from_std_error_and_display() {
        let e: Error = io_err().into();
        assert_eq!(e.to_string(), "gone");
    }

    #[test]
    fn context_on_std_result() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading x").unwrap_err();
        assert_eq!(e.to_string(), "reading x");
        assert!(format!("{e:?}").contains("gone"));
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert!(format!("{e:?}").contains("inner 7"));
        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero");
        assert_eq!(f(11).unwrap_err().to_string(), "too big: 11");
    }
}
