"""Round-trip tests for the python<->rust tensor bundle format."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.tensorio import MAGIC, load_tensors, save_tensors


def test_roundtrip_basic(tmp_path):
    p = str(tmp_path / "t.bin")
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": np.array([-1, 0, 7], np.int32),
        "c": np.array([[1, -2], [3, -4]], np.int8),
        "scalar": np.array(3.5, np.float32),
    }
    save_tensors(p, tensors)
    out = load_tensors(p)
    assert list(out) == list(tensors)
    for k in tensors:
        np.testing.assert_array_equal(out[k], tensors[k])
        assert out[k].dtype == tensors[k].dtype


def test_bad_magic_rejected(tmp_path):
    p = str(tmp_path / "bad.bin")
    with open(p, "wb") as f:
        f.write(b"NOTMAGIC" + b"\x00" * 16)
    with pytest.raises(ValueError):
        load_tensors(p)


def test_unsupported_dtype_rejected(tmp_path):
    p = str(tmp_path / "t.bin")
    with pytest.raises(TypeError):
        save_tensors(p, {"x": np.zeros(3, np.float64)})


@settings(max_examples=20, deadline=None)
@given(
    ndim=st.integers(0, 3),
    seed=st.integers(0, 2**31 - 1),
    code=st.sampled_from(["f32", "i32", "i8"]),
)
def test_roundtrip_hypothesis(ndim, seed, code):
    import tempfile
    tmp_path = tempfile.mkdtemp(prefix="tensorio_hyp_")
    from pathlib import Path
    tmp_path = Path(tmp_path)
    rng = np.random.default_rng(seed)
    shape = tuple(int(rng.integers(1, 5)) for _ in range(ndim))
    if code == "f32":
        arr = rng.normal(size=shape).astype(np.float32)
    elif code == "i32":
        arr = rng.integers(-1000, 1000, size=shape).astype(np.int32)
    else:
        arr = rng.integers(-128, 128, size=shape).astype(np.int8)
    p = str(tmp_path / f"h{seed}.bin")
    save_tensors(p, {"x": arr})
    out = load_tensors(p)["x"]
    np.testing.assert_array_equal(out, arr.reshape(shape))
