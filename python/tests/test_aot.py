"""AOT path checks: HLO text generation + manifest consistency.

These run the actual lowering for the standalone kernels (cheap) and
verify manifest/argument contracts. The full-encoder artifacts are
produced by ``make artifacts`` and validated end-to-end by the rust
integration tests.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.aot import to_hlo_text
from compile.kernels.sasp_gemm import sasp_gemm
from compile.model import ASR_TINY, ff_mask_shapes, param_names


def test_to_hlo_text_produces_parseable_module():
    def fn(x, y):
        return (jnp.matmul(x, y) + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_pallas_kernel_lowers_to_hlo_text():
    def fn(x, w, mask):
        return (sasp_gemm(x, w, mask, tile=4, interpret=True),)

    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    m = jax.ShapeDtypeStruct((2, 2), jnp.int32)
    text = to_hlo_text(jax.jit(fn).lower(x, w, m))
    assert "HloModule" in text
    # interpret-mode pallas lowers to plain HLO (no Mosaic custom-call)
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_gemm_kernel_export(tmp_path):
    aot.export_gemm_kernels(str(tmp_path))
    for name in ["sasp_gemm_t8", "quant_gemm_t8"]:
        hlo = tmp_path / f"{name}.hlo.txt"
        man = tmp_path / f"{name}_manifest.json"
        assert hlo.exists() and man.exists()
        manifest = json.loads(man.read_text())
        assert manifest["tile"] == 8
        assert manifest["output"]["shape"] == [64, 64]


def test_goldens_export(tmp_path):
    from compile.tensorio import load_tensors
    aot.export_goldens(str(tmp_path))
    g = load_tensors(str(tmp_path / "golden_gemm.bin"))
    assert set(g) == {"x", "w", "mask", "y", "w_q", "scale", "y_q"}
    # golden output actually equals masked matmul
    t = 8
    mask_e = np.repeat(np.repeat(g["mask"], t, 0), t, 1)
    np.testing.assert_allclose(g["y"], g["x"] @ (g["w"] * mask_e),
                               rtol=1e-4, atol=1e-4)


def test_manifest_arg_contract_matches_model():
    cfg = ASR_TINY
    names = param_names(cfg)
    # data(2) + masks(2*blocks) + params
    expected_args = 2 + 2 * cfg.n_blocks + len(names)
    mask_shapes = [s for pair in ff_mask_shapes(cfg) for s in pair]
    assert len(mask_shapes) == 2 * cfg.n_blocks
    assert expected_args == 2 + len(mask_shapes) + len(names)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(aot.ART, "asr_encoder_ref.hlo.txt")),
    reason="full artifacts not built yet (make artifacts)")
def test_built_artifacts_manifest_consistency():
    for name in ["asr_encoder_ref", "asr_encoder_sasp", "mt_encoder_ref"]:
        with open(os.path.join(aot.ART, f"{name}_manifest.json")) as f:
            man = json.load(f)
        hlo = open(os.path.join(aot.ART, f"{name}.hlo.txt")).read()
        assert "HloModule" in hlo
        assert man["output"]["shape"][0] == man["model"]["batch"]


@pytest.mark.skipif(
    not os.path.exists(os.path.join(aot.ART, "asr_encoder_ref.hlo.txt")),
    reason="full artifacts not built yet (make artifacts)")
def test_no_elided_constants_in_artifacts():
    """Regression: `constant({...})` in HLO text silently zero-fills on
    the rust side (xla_extension 0.5.1 text parser)."""
    import glob
    for p in glob.glob(os.path.join(aot.ART, "*.hlo.txt")):
        text = open(p).read().replace(" ", "")
        assert "constant({...}" not in text, p
