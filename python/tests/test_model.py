"""Layer-2 model checks: shapes, finiteness, pallas==oracle equivalence,
mask==zeroed-weights equivalence (the identity the rust QoS sweep relies
on), and data generators."""

import numpy as np
import pytest

from compile import data as D
from compile.model import (ASR_TINY, MT_TINY, asr_forward, ff_mask_shapes,
                           full_masks, init_params, mt_forward, num_params,
                           param_names)


@pytest.fixture(scope="module")
def asr_setup():
    cfg = ASR_TINY
    params = init_params(cfg, seed=0)
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(2, D.ASR_MAX_FRAMES, cfg.input_dim)).astype(
        np.float32)
    pad = np.ones((2, D.ASR_MAX_FRAMES), np.float32)
    return cfg, params, feats, pad


def test_param_order_is_stable(asr_setup):
    cfg, params, *_ = asr_setup
    assert list(params) == param_names(cfg)
    assert num_params(params) > 100_000


def test_asr_forward_shape_and_finite(asr_setup):
    cfg, params, feats, pad = asr_setup
    lp = asr_forward(params, feats, pad, full_masks(cfg), cfg,
                     use_pallas=False)
    assert lp.shape == (2, D.ASR_MAX_FRAMES, cfg.vocab)
    lp = np.asarray(lp)
    assert np.isfinite(lp).all()
    # log-softmax rows sum to 1 in prob space
    np.testing.assert_allclose(np.exp(lp).sum(-1), 1.0, rtol=1e-4)


def test_pallas_and_oracle_paths_agree(asr_setup):
    cfg, params, feats, pad = asr_setup
    masks = full_masks(cfg)
    a = np.asarray(asr_forward(params, feats, pad, masks, cfg,
                               use_pallas=True))
    b = np.asarray(asr_forward(params, feats, pad, masks, cfg,
                               use_pallas=False))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_mask_equals_zeroed_weights(asr_setup):
    """Running with a pruned mask == running dense with zeroed weight tiles.

    This identity is what lets the rust coordinator sweep tile sizes with
    the single dense artifact.
    """
    cfg, params, feats, pad = asr_setup
    t = cfg.tile
    masks = full_masks(cfg)
    m0 = np.asarray(masks[0]).copy()
    m0[1, 3] = 0
    m0[0, 0] = 0
    masks = [np.asarray(m) for m in masks]
    masks[0] = m0

    params_zeroed = dict(params)
    w1 = np.asarray(params["block0.ff.w1"]).copy()
    w1[1 * t:2 * t, 3 * t:4 * t] = 0.0
    w1[0:t, 0:t] = 0.0
    params_zeroed["block0.ff.w1"] = w1

    a = np.asarray(asr_forward(params, feats, pad, masks, cfg,
                               use_pallas=False))
    b = np.asarray(asr_forward(params_zeroed, feats, pad, full_masks(cfg),
                               cfg, use_pallas=False))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_pad_mask_blocks_attention(asr_setup):
    """Changing padded frames must not change valid-frame outputs."""
    cfg, params, feats, _ = asr_setup
    pad = np.ones((2, D.ASR_MAX_FRAMES), np.float32)
    pad[:, 50:] = 0.0
    feats2 = feats.copy()
    feats2[:, 50:] = 123.0
    a = np.asarray(asr_forward(params, feats, pad, full_masks(cfg), cfg,
                               use_pallas=False))
    b = np.asarray(asr_forward(params, feats2, pad, full_masks(cfg), cfg,
                               use_pallas=False))
    np.testing.assert_allclose(a[:, :50], b[:, :50], rtol=1e-4, atol=1e-4)


def test_mt_forward_shape():
    cfg = MT_TINY
    params = init_params(cfg, seed=1)
    src = np.zeros((2, D.MT_SEQ_LEN), np.int32)
    out = mt_forward(params, src, full_masks(cfg), cfg, use_pallas=False)
    assert out.shape == (2, D.MT_SEQ_LEN, cfg.vocab)


def test_ff_mask_shapes_cover_all_blocks():
    cfg = ASR_TINY
    shapes = ff_mask_shapes(cfg)
    assert len(shapes) == cfg.n_blocks
    t = cfg.tile
    assert shapes[0][0] == (cfg.d_model // t, cfg.d_ff // t)
    assert shapes[0][1] == (cfg.d_ff // t, cfg.d_model // t)


# --- data generators -----------------------------------------------------------


def test_asr_dataset_deterministic():
    _, (f1, fl1, l1, ll1) = D.make_asr_dataset(5, 4)
    _, (f2, fl2, l2, ll2) = D.make_asr_dataset(5, 4)
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_array_equal(l1, l2)


def test_asr_dataset_lengths_valid():
    _, (feats, flen, labels, llen) = D.make_asr_dataset(6, 8)
    assert (flen >= llen).all()  # >=1 frame per char
    assert (flen <= D.ASR_MAX_FRAMES).all()
    assert (labels[np.arange(8), np.maximum(llen - 1, 0)] < D.CTC_BLANK).all()


def test_mt_translate_is_remap_plus_swaps():
    table = D.mt_remap_table()
    src = np.array([1, 2, 3, D.MT_SWAP_TOKEN, 4, 5, 6], np.int32)
    tgt = D.mt_translate(src)
    np.testing.assert_array_equal(tgt[:3], table[src[:3]])
    assert tgt[4] == table[5] and tgt[5] == table[4]  # swapped pair
    assert tgt[6] == table[6]


def test_mt_remap_is_bijection():
    table = D.mt_remap_table()
    assert sorted(table.tolist()) == list(range(D.MT_VOCAB))


def test_pos_enc_arg_matches_default_path(asr_setup):
    """Regression: the AOT path passes the PE table as an argument (XLA's
    HLO-text printer elides large constants; the 0.5.1 parser zero-fills
    them). Both paths must be numerically identical."""
    from compile.model import sinusoidal_pe
    cfg, params, feats, pad = asr_setup
    masks = full_masks(cfg)
    a = np.asarray(asr_forward(params, feats, pad, masks, cfg,
                               use_pallas=False))
    pe = sinusoidal_pe(feats.shape[1], cfg.d_model)
    b = np.asarray(asr_forward(params, feats, pad, masks, cfg,
                               pos_enc=pe, use_pallas=False))
    np.testing.assert_array_equal(a, b)
