"""CTC loss vs brute-force path enumeration + decode behaviour."""

import itertools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.ctc import ctc_loss, greedy_decode


def brute_force_ctc(lp, lab, blank):
    """Enumerate all alignment paths (tiny cases only)."""
    t_total, v = lp.shape
    tot = -np.inf
    for path in itertools.product(range(v), repeat=t_total):
        seq, prev = [], -1
        for s in path:
            if s != prev and s != blank:
                seq.append(s)
            prev = s
        if seq == list(lab):
            tot = np.logaddexp(tot, sum(lp[t, path[t]] for t in range(t_total)))
    return -tot


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    t_len=st.integers(3, 5),
    lab_len=st.integers(1, 3),
    vocab=st.integers(3, 4),
)
def test_ctc_matches_brute_force(seed, t_len, lab_len, vocab):
    if lab_len > t_len:
        lab_len = t_len
    rng = np.random.default_rng(seed)
    blank = vocab - 1
    lp = np.log(rng.dirichlet(np.ones(vocab), size=t_len)).astype(np.float32)
    # labels must not contain blank; repeated labels cost extra frames
    lab = rng.integers(0, blank, size=lab_len).astype(np.int32)
    needed = lab_len + sum(lab[i] == lab[i - 1] for i in range(1, lab_len))
    if needed > t_len:
        return  # no valid path exists; skip degenerate case
    got = float(ctc_loss(lp[None], np.array([t_len], np.int32), lab[None],
                         np.array([lab_len], np.int32), blank=blank)[0])
    want = brute_force_ctc(lp, lab, blank)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_ctc_feat_len_masks_tail():
    """Frames beyond feat_len must not affect the loss."""
    rng = np.random.default_rng(0)
    lp1 = np.log(rng.dirichlet(np.ones(5), size=8)).astype(np.float32)
    lp2 = lp1.copy()
    lp2[6:] = np.log(rng.dirichlet(np.ones(5), size=2)).astype(np.float32)
    lab = np.array([[1, 2]], np.int32)
    args = (np.array([6], np.int32), lab, np.array([2], np.int32))
    a = float(ctc_loss(lp1[None], *args, blank=4)[0])
    b = float(ctc_loss(lp2[None], *args, blank=4)[0])
    assert a == pytest.approx(b, rel=1e-6)


def test_greedy_decode_collapses_and_drops_blank():
    # vocab=3, blank=2; frames: [0,0,2,1,1,2,1]
    path = np.array([0, 0, 2, 1, 1, 2, 1])
    lp = np.full((1, 7, 3), -10.0, np.float32)
    for t, s in enumerate(path):
        lp[0, t, s] = 0.0
    out = greedy_decode(lp, np.array([7]), blank=2)
    assert out == [[0, 1, 1]]


def test_greedy_decode_respects_feat_len():
    lp = np.full((1, 5, 3), -10.0, np.float32)
    lp[0, :, 0] = 0.0
    out = greedy_decode(lp, np.array([2]), blank=2)
    assert out == [[0]]
