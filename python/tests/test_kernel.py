"""Layer-1 correctness: Pallas SASP kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, tile sizes, and mask densities — the CORE
correctness signal for the compute hot-spot.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.sasp_gemm import sasp_gemm, sasp_quant_gemm
from compile.kernels.ref import (dequantize_ref, expand_tile_mask,
                                 quantize_ref, sasp_gemm_ref,
                                 sasp_quant_gemm_ref)


def _rand(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


def _mask(rng, kt, nt, density):
    m = (rng.random((kt, nt)) < density).astype(np.int32)
    return m


# --- fixed-shape smoke tests ---------------------------------------------------


@pytest.mark.parametrize("tile", [4, 8, 16])
@pytest.mark.parametrize("density", [0.0, 0.5, 1.0])
def test_sasp_gemm_matches_ref(tile, density):
    rng = np.random.default_rng(tile * 100 + int(density * 10))
    m, k, n = 32, 4 * tile, 6 * tile
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    mask = _mask(rng, k // tile, n // tile, density)
    got = np.asarray(sasp_gemm(x, w, mask, tile=tile))
    want = np.asarray(sasp_gemm_ref(x, w, mask, tile=tile))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("tile", [4, 8])
def test_sasp_quant_gemm_matches_ref(tile):
    rng = np.random.default_rng(7)
    m, k, n = 16, 4 * tile, 4 * tile
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    mask = _mask(rng, k // tile, n // tile, 0.6)
    w_q, scale = quantize_ref(jnp.asarray(w))
    got = np.asarray(sasp_quant_gemm(x, w_q, scale, mask, tile=tile))
    want = np.asarray(sasp_quant_gemm_ref(x, w_q, scale, mask, tile=tile))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_zero_mask_gives_zero_output():
    rng = np.random.default_rng(1)
    x, w = _rand(rng, 16, 32), _rand(rng, 32, 32)
    mask = np.zeros((4, 4), np.int32)
    got = np.asarray(sasp_gemm(x, w, mask, tile=8))
    assert np.all(got == 0.0)


def test_mask_row_zero_matches_dense_partial():
    """Pruning one K-row of tiles must equal zeroing those weight rows."""
    rng = np.random.default_rng(2)
    tile = 8
    x, w = _rand(rng, 16, 32), _rand(rng, 32, 24)
    mask = np.ones((4, 3), np.int32)
    mask[1, :] = 0
    w_masked = w.copy()
    w_masked[tile:2 * tile, :] = 0.0
    got = np.asarray(sasp_gemm(x, w, mask, tile=tile))
    np.testing.assert_allclose(got, x @ w_masked, rtol=1e-5, atol=1e-4)


# --- hypothesis sweeps ---------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    mt=st.integers(1, 4), kt=st.integers(1, 5), nt=st.integers(1, 5),
    tile=st.sampled_from([4, 8]),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sasp_gemm_hypothesis(mt, kt, nt, tile, density, seed):
    rng = np.random.default_rng(seed)
    m, k, n = mt * tile, kt * tile, nt * tile
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    mask = _mask(rng, kt, nt, density)
    got = np.asarray(sasp_gemm(x, w, mask, tile=tile))
    want = np.asarray(sasp_gemm_ref(x, w, mask, tile=tile))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    kt=st.integers(1, 4), nt=st.integers(1, 4),
    tile=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_gemm_hypothesis(kt, nt, tile, seed):
    rng = np.random.default_rng(seed)
    m, k, n = 2 * tile, kt * tile, nt * tile
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    mask = _mask(rng, kt, nt, 0.7)
    w_q, scale = quantize_ref(jnp.asarray(w))
    got = np.asarray(sasp_quant_gemm(x, w_q, scale, mask, tile=tile))
    want = np.asarray(sasp_quant_gemm_ref(x, w_q, scale, mask, tile=tile))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


# --- quantizer properties ------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale_pow=st.integers(-3, 3))
def test_quantize_roundtrip_error_bound(seed, scale_pow):
    """|dequant(quant(w)) - w| <= scale/2 elementwise (symmetric PTQ)."""
    rng = np.random.default_rng(seed)
    w = (rng.normal(size=(16, 16)) * 10.0 ** scale_pow).astype(np.float32)
    w_q, scale = quantize_ref(jnp.asarray(w))
    err = np.abs(np.asarray(dequantize_ref(w_q, scale)) - w)
    assert np.all(err <= float(scale) / 2 + 1e-7)


def test_quantize_all_zero_weights():
    w_q, scale = quantize_ref(jnp.zeros((8, 8)))
    assert float(scale) == 1.0
    assert np.all(np.asarray(w_q) == 0)


def test_expand_tile_mask_shapes():
    m = jnp.asarray(np.arange(6).reshape(2, 3) % 2, jnp.int32)
    e = np.asarray(expand_tile_mask(m, 4))
    assert e.shape == (8, 12)
    assert np.all(e[:4, :4] == 0) and np.all(e[:4, 4:8] == 1)
