"""Layer-2 JAX model: transformer encoder with SASP feed-forward GEMMs.

The architecture mirrors the paper's ESPnet encoder blocks (pre-LN MHSA +
feed-forward), scaled down to the synthetic tasks. The feed-forward GEMMs —
the layers the paper prunes (§3.1: "feed-forward GEMMs are much more
amenable to pruning than attention ones") — are routed through the Layer-1
Pallas kernel ``sasp_gemm`` so that the lowered HLO contains the
block-sparse compute path and the tile masks are *runtime inputs*: the rust
coordinator prunes weights, builds masks, and re-runs inference without
ever re-lowering.

Weights are HLO arguments (not constants) for the same reason.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.sasp_gemm import sasp_gemm
from .kernels.ref import sasp_gemm_ref

Params = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Shape hyper-parameters (a scaled-down Table 1 row)."""

    name: str = "asr_tiny"
    input_dim: int = 40            # acoustic features (ASR) — unused for MT
    vocab: int = 28                # output vocabulary (incl. CTC blank)
    d_model: int = 64
    n_heads: int = 4
    d_ff: int = 256
    n_blocks: int = 4
    tile: int = 8                  # SASP tile baked into the AOT artifact
    token_input: bool = False      # MT: embed int tokens instead of feats

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


ASR_TINY = ModelConfig()
MT_TINY = ModelConfig(
    name="mt_tiny", input_dim=32, vocab=32, d_model=64, n_heads=4,
    d_ff=256, n_blocks=2, token_input=True,
)


# --- parameters ---------------------------------------------------------------


def param_names(cfg: ModelConfig) -> List[str]:
    """Deterministic parameter ordering — the AOT argument contract.

    The rust coordinator reproduces this exact order when assembling the
    PJRT argument list (see ``artifacts/*_manifest.json``).
    """
    names = ["in_proj.w", "in_proj.b"]
    for i in range(cfg.n_blocks):
        p = f"block{i}."
        names += [
            p + "ln1.g", p + "ln1.b",
            p + "attn.wq", p + "attn.wk", p + "attn.wv", p + "attn.wo",
            p + "ln2.g", p + "ln2.b",
            p + "ff.w1", p + "ff.b1", p + "ff.w2", p + "ff.b2",
        ]
    names += ["ln_f.g", "ln_f.b", "head.w", "head.b"]
    return names


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Scaled-normal init; biases zero, LayerNorm gains one."""
    rng = np.random.default_rng(seed)
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab

    def dense(m, n):
        return jnp.asarray(
            rng.normal(0, (2.0 / (m + n)) ** 0.5, size=(m, n)), jnp.float32
        )

    p: Params = {}
    p["in_proj.w"] = (
        dense(cfg.vocab, d) if cfg.token_input else dense(cfg.input_dim, d)
    )
    p["in_proj.b"] = jnp.zeros(d, jnp.float32)
    for i in range(cfg.n_blocks):
        pre = f"block{i}."
        p[pre + "ln1.g"] = jnp.ones(d, jnp.float32)
        p[pre + "ln1.b"] = jnp.zeros(d, jnp.float32)
        p[pre + "attn.wq"] = dense(d, d)
        p[pre + "attn.wk"] = dense(d, d)
        p[pre + "attn.wv"] = dense(d, d)
        p[pre + "attn.wo"] = dense(d, d)
        p[pre + "ln2.g"] = jnp.ones(d, jnp.float32)
        p[pre + "ln2.b"] = jnp.zeros(d, jnp.float32)
        p[pre + "ff.w1"] = dense(d, f)
        p[pre + "ff.b1"] = jnp.zeros(f, jnp.float32)
        p[pre + "ff.w2"] = dense(f, d)
        p[pre + "ff.b2"] = jnp.zeros(d, jnp.float32)
    p["ln_f.g"] = jnp.ones(d, jnp.float32)
    p["ln_f.b"] = jnp.zeros(d, jnp.float32)
    p["head.w"] = dense(d, v)
    p["head.b"] = jnp.zeros(v, jnp.float32)
    assert list(p) == param_names(cfg)
    return p


def num_params(p: Params) -> int:
    return int(sum(np.prod(a.shape) for a in p.values()))


def ff_mask_shapes(cfg: ModelConfig) -> List[Tuple[Tuple[int, int], Tuple[int, int]]]:
    """Per-block (mask_w1, mask_w2) tile-mask shapes for the baked tile."""
    t = cfg.tile
    return [
        ((cfg.d_model // t, cfg.d_ff // t), (cfg.d_ff // t, cfg.d_model // t))
        for _ in range(cfg.n_blocks)
    ]


def full_masks(cfg: ModelConfig) -> List[jnp.ndarray]:
    """All-ones masks (dense execution), flattened [m1_0, m2_0, m1_1, ...]."""
    out = []
    for s1, s2 in ff_mask_shapes(cfg):
        out += [jnp.ones(s1, jnp.int32), jnp.ones(s2, jnp.int32)]
    return out


# --- forward ------------------------------------------------------------------


def _layer_norm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(x, wq, wk, wv, wo, pad_mask, cfg: ModelConfig):
    """Standard MHSA. ``pad_mask``: f32[B, T], 1 = valid frame."""
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ wq).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    k = (x @ wk).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(hd)
    scores = scores + (1.0 - pad_mask[:, None, None, :]) * jnp.float32(-1e9)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
    return out.transpose(0, 2, 1, 3).reshape(b, t, d) @ wo


def _ff_sasp(x2d, w, b, mask, tile: int, interpret: bool, use_pallas: bool):
    """Feed-forward GEMM through the SASP kernel (or the jnp oracle)."""
    if use_pallas:
        y = sasp_gemm(x2d, w, mask, tile=tile, interpret=interpret)
    else:
        y = sasp_gemm_ref(x2d, w, mask, tile=tile)
    return y + b


def sinusoidal_pe(t: int, d: int) -> np.ndarray:
    """Fixed sinusoidal position encoding table ``f32[t, d]``."""
    pos = np.arange(t)[:, None]
    dim = np.arange(d)[None, :]
    angle = pos / np.power(10000.0, (2 * (dim // 2)) / d)
    return np.where(dim % 2 == 0, np.sin(angle), np.cos(angle)).astype(
        np.float32)


def encoder_forward(params: Params, x, pad_mask, masks: List[jnp.ndarray],
                    cfg: ModelConfig, *, pos_enc=None,
                    use_pallas: bool = True, interpret: bool = True):
    """Run the encoder stack.

    Args:
      x: ``f32[B, T, input_dim]`` features, or ``int32[B, T]`` tokens when
        ``cfg.token_input``.
      pad_mask: ``f32[B, T]`` validity mask.
      masks: flattened per-block FF tile masks ``[m1_0, m2_0, m1_1, ...]``.
      pos_enc: optional ``f32[T, d_model]`` position table. The AOT path
        passes it as an *argument*: XLA's HLO-text printer elides large
        constants (``constant({...})``), which the 0.5.1 text parser
        zero-fills — constants this size must not be baked in.

    Returns ``f32[B, T, vocab]`` logits.
    """
    if cfg.token_input:
        h = params["in_proj.w"][x] + params["in_proj.b"]
    else:
        h = x @ params["in_proj.w"] + params["in_proj.b"]
    bsz, t, d = h.shape
    if pos_enc is None:
        pos_enc = jnp.asarray(sinusoidal_pe(t, d))
    h = h + pos_enc[None]

    for i in range(cfg.n_blocks):
        p = f"block{i}."
        hn = _layer_norm(h, params[p + "ln1.g"], params[p + "ln1.b"])
        h = h + _attention(
            hn, params[p + "attn.wq"], params[p + "attn.wk"],
            params[p + "attn.wv"], params[p + "attn.wo"], pad_mask, cfg,
        )
        hn = _layer_norm(h, params[p + "ln2.g"], params[p + "ln2.b"])
        x2d = hn.reshape(bsz * t, d)
        y = _ff_sasp(x2d, params[p + "ff.w1"], params[p + "ff.b1"],
                     masks[2 * i], cfg.tile, interpret, use_pallas)
        y = jax.nn.relu(y)
        y = _ff_sasp(y, params[p + "ff.w2"], params[p + "ff.b2"],
                     masks[2 * i + 1], cfg.tile, interpret, use_pallas)
        h = h + y.reshape(bsz, t, d)

    h = _layer_norm(h, params["ln_f.g"], params["ln_f.b"])
    return h @ params["head.w"] + params["head.b"]


def asr_forward(params: Params, feats, pad_mask, masks, cfg: ModelConfig,
                **kw):
    """ASR: encoder + CTC log-probs, ``f32[B, T, vocab]``."""
    logits = encoder_forward(params, feats, pad_mask, masks, cfg, **kw)
    return jax.nn.log_softmax(logits, axis=-1)


def mt_forward(params: Params, src, masks, cfg: ModelConfig, **kw):
    """MT: encoder over tokens, per-position target logits."""
    pad_mask = jnp.ones(src.shape, jnp.float32)
    return encoder_forward(params, src, pad_mask, masks, cfg, **kw)
