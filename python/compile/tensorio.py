"""Tiny binary tensor-bundle format shared between python (writer) and the
rust coordinator (reader: ``rust/src/data/tensorfile.rs``).

Layout (all little-endian):

    magic   : 8 bytes  b"SASPTNS1"
    count   : u32
    per tensor:
        name_len : u32, name bytes (utf-8)
        dtype    : u8   (0 = f32, 1 = i32, 2 = i8)
        ndim     : u32, dims u32 * ndim
        data     : raw bytes, C order

Kept deliberately dumb — no compression, no alignment tricks — so both
sides are ~60 lines and fully testable.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"SASPTNS1"
_DTYPES = {0: np.float32, 1: np.int32, 2: np.int8}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1, np.dtype(np.int8): 2}


def save_tensors(path: str, tensors: dict[str, np.ndarray]) -> None:
    """Write an ordered name->array bundle. Order is preserved on load."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _CODES:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", _CODES[arr.dtype]))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def load_tensors(path: str) -> dict[str, np.ndarray]:
    """Read a bundle written by :func:`save_tensors` (round-trip tested)."""
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(8) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            (code,) = struct.unpack("<B", f.read(1))
            (ndim,) = struct.unpack("<I", f.read(4))
            shape = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dtype = np.dtype(_DTYPES[code])
            n = int(np.prod(shape)) if shape else 1
            data = f.read(n * dtype.itemsize)
            out[name] = np.frombuffer(data, dtype=dtype).reshape(shape).copy()
    return out
