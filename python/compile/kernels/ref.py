"""Pure-jnp oracles for the Layer-1 Pallas kernels.

These are the ground truth the pytest/hypothesis suites compare against;
they are also what the L2 model falls back to for tile sizes that do not
divide the model dimensions.
"""

from __future__ import annotations

import jax.numpy as jnp


def expand_tile_mask(tile_mask, tile: int):
    """``int[KT, NT] -> f32[KT*tile, NT*tile]`` elementwise 0/1 mask."""
    return jnp.repeat(
        jnp.repeat(tile_mask.astype(jnp.float32), tile, axis=0), tile, axis=1
    )


def sasp_gemm_ref(x, w, tile_mask, *, tile: int = 8):
    """Reference block-sparse GEMM: mask weights elementwise, then matmul."""
    return x @ (w * expand_tile_mask(tile_mask, tile))


def quantize_ref(w, bits: int = 8):
    """Per-tensor symmetric sign-magnitude quantization of weights.

    Returns ``(w_q int8, scale f32[])`` with
    ``scale = max|w| / (2**(bits-1) - 1)`` — the paper's PTQ scheme for the
    hybrid FP32_INT8 PE (sign-and-magnitude, so the representable range is
    symmetric: [-127, 127] for 8 bits).
    """
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(w))
    scale = jnp.where(amax > 0, amax / qmax, 1.0).astype(jnp.float32)
    w_q = jnp.clip(jnp.round(w / scale), -qmax, qmax).astype(jnp.int8)
    return w_q, scale


def dequantize_ref(w_q, scale):
    return w_q.astype(jnp.float32) * scale


def sasp_quant_gemm_ref(x, w_q, scale, tile_mask, *, tile: int = 8):
    """Reference for the INT8-weight block-sparse GEMM."""
    w = dequantize_ref(w_q, scale)
    return x @ (w * expand_tile_mask(tile_mask, tile))
