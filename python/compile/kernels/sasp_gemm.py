"""Layer-1 Pallas kernels: SASP block-sparse GEMM (+ INT8-weight variant).

The paper's core hardware insight — a weight-stationary systolic array can
*skip* an entire weight tile whose values are all zero (no weight
programming, no input streaming, no partial-product accumulation) — is
expressed here for the TPU stack:

- the systolic tile == the Pallas block: ``BlockSpec`` schedules the
  HBM->VMEM movement that the paper performs with custom PROG_WEIGHT /
  STREAM_IO instructions;
- the SASP elision is ``@pl.when(mask[k, j])`` around the block matmul —
  a pruned tile contributes neither MXU work nor (on real hardware) the
  VMEM fill for the weight block;
- the MXU systolic array plays the role of the paper's PE mesh.

Kernels are lowered with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); correctness is asserted against ``ref.py`` by the
pytest suite, and real-TPU efficiency is estimated analytically in
DESIGN.md / EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _m_block(m: int, tile: int) -> int:
    """M-dimension block size (§Perf L1 iteration 1).

    The weight tile is fixed at ``tile x tile`` by the SASP co-design, but
    the streamed M dimension is free: taller M-blocks mean fewer grid
    steps (64x fewer for the encoder shapes) and better MXU occupancy on
    real hardware, at ~`4*tm*tile*3` bytes of VMEM (~48 KiB at tm=512,
    far under budget). Pick the largest divisor of ``m`` that is a
    multiple of ``tile`` and at most 512; fall back to ``m`` when the
    batch dimension is not tile-aligned.
    """
    if m % tile != 0:
        return m
    tm = 512
    while tm >= tile:
        if m % tm == 0:
            return tm
        tm -= tile
    return tile


def _sasp_gemm_kernel(x_ref, w_ref, mask_ref, o_ref, *, n_kt: int):
    """One (i, j, k) grid step of the block-sparse GEMM.

    Grid order is (i, j, k) with k innermost so the f32 accumulation into
    ``o_ref`` is sequential per output block (classic weight-stationary
    tiling: the output tile stays resident while K-tiles stream).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    # SASP tile skip: a pruned (all-zero) weight tile is elided entirely.
    @pl.when(mask_ref[0, 0] != 0)
    def _mac():
        o_ref[...] += jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.float32
        )


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def sasp_gemm(x, w, tile_mask, *, tile: int = 8, interpret: bool = True):
    """Block-sparse GEMM ``x @ (w * expand(tile_mask))``.

    Args:
      x: ``f32[M, K]`` activations.
      w: ``f32[K, N]`` weights. Tiles where ``tile_mask`` is 0 are treated
        as (and asserted by tests to be) zero.
      tile_mask: ``int32[K // tile, N // tile]`` — 1 = keep, 0 = pruned.
      tile: SASP tile size == systolic array dimension (square array).

    Returns:
      ``f32[M, N]``.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert k % tile == 0 and n % tile == 0, "K, N must be tile-aligned"
    assert tile_mask.shape == (k // tile, n // tile), tile_mask.shape
    tm = _m_block(m, tile)
    grid = (m // tm, n // tile, k // tile)

    return pl.pallas_call(
        functools.partial(_sasp_gemm_kernel, n_kt=k // tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tile), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tile, tile), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tile), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w, tile_mask.astype(jnp.int32))


def _sasp_quant_gemm_kernel(x_ref, wq_ref, scale_ref, mask_ref, o_ref):
    """INT8-weight variant: dequantize the live tile in VMEM, then MAC.

    Mirrors the paper's hybrid FP32_INT8 PE (§3.3): activations stay FP32,
    weights are INT8 magnitudes scaled per tensor; the multiply happens at
    FP32 precision after expansion, and the accumulator is FP32 — exactly
    the numerics of the hybrid multiplier up to its truncation step.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(mask_ref[0, 0] != 0)
    def _mac():
        w_f32 = wq_ref[...].astype(jnp.float32) * scale_ref[0]
        o_ref[...] += jnp.dot(
            x_ref[...], w_f32, preferred_element_type=jnp.float32
        )


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def sasp_quant_gemm(x, w_q, scale, tile_mask, *, tile: int = 8,
                    interpret: bool = True):
    """Block-sparse GEMM with INT8 weights: ``x @ (dequant(w_q) * mask)``.

    Args:
      x: ``f32[M, K]`` activations.
      w_q: ``int8[K, N]`` quantized weights.
      scale: ``f32[1]`` per-tensor dequantization scale.
      tile_mask: ``int32[K // tile, N // tile]``.
    """
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2 and k % tile == 0 and n % tile == 0
    assert tile_mask.shape == (k // tile, n // tile)
    tm = _m_block(m, tile)
    grid = (m // tm, n // tile, k // tile)

    return pl.pallas_call(
        _sasp_quant_gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tile), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tile, tile), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1,), lambda i, j, kk: (0,)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tile), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w_q, scale.reshape(1), tile_mask.astype(jnp.int32))
