"""CTC loss (log-alpha forward recursion) + greedy decoding in pure JAX.

The paper's ASR models are ESPnet hybrid CTC/attention; our synthetic
stand-in trains a CTC-only encoder (the encoder is the part the paper
prunes and accelerates — "its execution dominates run-time", §4.1).

Implemented from scratch (no optax/ESPnet here): standard Graves-style
forward algorithm over the blank-extended label sequence, vmapped over the
batch, with per-utterance feature/label lengths handled by masking. The
pytest suite validates it against a brute-force path enumeration on small
cases.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-1e30)


def _extend(labels, blank: int):
    """[l1..lL] -> [b, l1, b, l2, ..., lL, b] (padded labels included)."""
    length = labels.shape[0]
    ext = jnp.full(2 * length + 1, blank, labels.dtype)
    return ext.at[1::2].set(labels)


@functools.partial(jax.jit, static_argnames=("blank",))
def ctc_loss(log_probs, feat_len, labels, label_len, *, blank: int):
    """Batched negative log-likelihood.

    Args:
      log_probs: ``f32[B, T, V]`` log-softmax outputs.
      feat_len:  ``i32[B]`` valid frame counts (<= T).
      labels:    ``i32[B, L]`` padded label sequences.
      label_len: ``i32[B]`` valid label counts (<= L).
      blank:     CTC blank index.

    Returns ``f32[B]`` per-utterance NLL.
    """

    def single(lp, t_len, lab, l_len):
        t_total = lp.shape[0]
        ext = _extend(lab, blank)
        s = ext.shape[0]
        # Skip transition s-2 -> s allowed when ext[s] is a label that
        # differs from ext[s-2].
        prev2 = jnp.concatenate([jnp.full(2, -1, ext.dtype), ext[:-2]])
        skip = (ext != blank) & (ext != prev2)

        alpha0 = jnp.full(s, NEG_INF)
        alpha0 = alpha0.at[0].set(lp[0, blank])
        alpha0 = alpha0.at[1].set(lp[0, ext[1]])

        def step(alpha, t):
            a1 = jnp.concatenate([jnp.array([NEG_INF]), alpha[:-1]])
            a2 = jnp.concatenate([jnp.full(2, NEG_INF), alpha[:-2]])
            merged = jnp.logaddexp(alpha, a1)
            merged = jnp.where(skip, jnp.logaddexp(merged, a2), merged)
            new = merged + lp[t, ext]
            # Past the end of the utterance the lattice is frozen.
            new = jnp.where(t < t_len, new, alpha)
            return new, None

        alpha, _ = jax.lax.scan(step, alpha0, jnp.arange(1, t_total))
        s_eff = 2 * l_len + 1
        end = jnp.logaddexp(
            alpha[jnp.maximum(s_eff - 1, 0)], alpha[jnp.maximum(s_eff - 2, 0)]
        )
        return -end

    return jax.vmap(single)(log_probs, feat_len, labels, label_len)


def greedy_decode(log_probs, feat_len, *, blank: int):
    """Best-path decode: argmax per frame, collapse repeats, drop blanks.

    Plain numpy/python (not traced) — used for training diagnostics; the
    rust ``qos`` module reimplements it for evaluation.
    """
    import numpy as np

    lp = np.asarray(log_probs)
    outs = []
    for b in range(lp.shape[0]):
        path = lp[b, : int(feat_len[b])].argmax(axis=-1)
        seq, prev = [], -1
        for sym in path:
            if sym != prev and sym != blank:
                seq.append(int(sym))
            prev = sym
        outs.append(seq)
    return outs
