"""Synthetic stand-ins for the paper's corpora.

The paper evaluates on LibriSpeech (ASR, WER) and MuST-C (ASR+MT cascade,
BLEU) — neither of which (nor the 960 h of GPU training they imply) is
available here. Per the substitution rule, we build synthetic tasks that
exercise the *same code paths* and, crucially, yield trained transformer
weights whose tile-L1-norm distribution drives the same QoS-vs-pruning
trade-off the paper studies:

- **ASR**: each character of a small alphabet has a fixed random "acoustic
  template" in feature space; an utterance emits 2-4 noisy frames per
  character. The model is a transformer encoder + CTC head; QoS is WER on
  a held-out test set, exactly the paper's metric.
- **MT**: a deterministic synthetic language pair — token remap plus local
  reordering (adjacent-pair swap for marked tokens) — scored with BLEU.

Everything is seeded, so python (training) and rust (evaluation) see the
same test set via the tensor bundle in ``artifacts/``.
"""

from __future__ import annotations

import numpy as np

# --- ASR task ---------------------------------------------------------------

ASR_VOCAB = 28          # 26 letters + space; CTC blank = index 27
CTC_BLANK = ASR_VOCAB - 1
ASR_FEAT_DIM = 40       # "fbank"-like feature dimension
ASR_MAX_FRAMES = 96     # padded frame count
ASR_MAX_LABEL = 24      # padded label length (0-padded, 0 is a real symbol
                        # so lengths are carried separately)


def _char_templates(rng: np.random.Generator) -> np.ndarray:
    """Fixed per-character acoustic templates, orthonormalized.

    Orthonormal templates keep the classes separable at the frame level
    (like distinct phones); difficulty comes from frame noise, variable
    repetition counts, and the CTC alignment problem.
    """
    t = rng.normal(size=(ASR_FEAT_DIM, ASR_VOCAB - 1))
    q, _ = np.linalg.qr(t)
    return np.ascontiguousarray(q.T.astype(np.float32))


def make_asr_batch(rng: np.random.Generator, templates: np.ndarray,
                   batch: int, noise: float = 0.30):
    """Returns (feats [B,T,F], feat_len [B], labels [B,L], label_len [B])."""
    feats = np.zeros((batch, ASR_MAX_FRAMES, ASR_FEAT_DIM), np.float32)
    labels = np.zeros((batch, ASR_MAX_LABEL), np.int32)
    feat_len = np.zeros(batch, np.int32)
    label_len = np.zeros(batch, np.int32)
    for b in range(batch):
        n_chars = int(rng.integers(6, 22))
        seq = rng.integers(0, ASR_VOCAB - 1, size=n_chars)
        t = 0
        for i, c in enumerate(seq):
            reps = int(rng.integers(2, 5))
            for _ in range(reps):
                if t >= ASR_MAX_FRAMES:
                    break
                feats[b, t] = templates[c] + noise * rng.normal(
                    size=ASR_FEAT_DIM
                ).astype(np.float32)
                t += 1
        labels[b, :n_chars] = seq
        feat_len[b] = t
        label_len[b] = n_chars
    return feats, feat_len, labels, label_len


def make_asr_dataset(seed: int, n_utts: int):
    """Deterministic dataset: templates + a batch of utterances."""
    rng = np.random.default_rng(seed)
    templates = _char_templates(rng)
    return templates, make_asr_batch(rng, templates, n_utts)


# --- MT task ----------------------------------------------------------------

MT_VOCAB = 32           # source/target share a vocabulary size
MT_SEQ_LEN = 32
MT_SWAP_TOKEN = 0       # source token that swaps the following pair
_REMAP_SEED = 1234


def mt_remap_table() -> np.ndarray:
    """Fixed bijective token remap (the 'lexicon' of the toy language)."""
    rng = np.random.default_rng(_REMAP_SEED)
    return rng.permutation(MT_VOCAB).astype(np.int32)


def mt_translate(src: np.ndarray) -> np.ndarray:
    """Ground-truth translation: remap every token, then swap the two
    tokens following every occurrence of ``MT_SWAP_TOKEN`` (local
    reordering, the phenomenon that makes the task need attention)."""
    table = mt_remap_table()
    tgt = table[src].copy()
    out = tgt.copy()
    i = 0
    n = len(src)
    while i < n:
        if src[i] == MT_SWAP_TOKEN and i + 2 < n:
            out[i + 1], out[i + 2] = tgt[i + 2], tgt[i + 1]
            i += 3
        else:
            i += 1
    return out


def make_mt_dataset(seed: int, n_sents: int):
    """Returns (src [B,L] int32, tgt [B,L] int32)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, MT_VOCAB, size=(n_sents, MT_SEQ_LEN)).astype(np.int32)
    tgt = np.stack([mt_translate(s) for s in src]).astype(np.int32)
    return src, tgt
