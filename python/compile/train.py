"""Build-time training of the synthetic ASR and MT models (pure JAX).

This is the stand-in for the paper's ESPnet training runs (Table 1). Adam
is implemented inline (no optax in this environment). Training uses the
jnp oracle path of the SASP GEMM (differentiable and fast); the Pallas
path is exercised by the AOT artifacts and the pytest equivalence suite.

Outputs (all consumed by the rust coordinator):
    artifacts/params_asr.bin / params_mt.bin   — trained weights
    artifacts/testset_asr.bin / testset_mt.bin — held-out eval data
    artifacts/train_log_asr.json / _mt.json    — loss curves (EXPERIMENTS.md)
"""

from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from .ctc import ctc_loss, greedy_decode
from .model import (ASR_TINY, MT_TINY, ModelConfig, asr_forward, full_masks,
                    init_params, mt_forward, num_params)
from .tensorio import save_tensors

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

ASR_TRAIN_STEPS = 2500
MT_TRAIN_STEPS = 800
BATCH = 32
LR_PEAK, LR_FLOOR, WARMUP = 3e-3, 1e-4, 100


def lr_at(step: int, total: int) -> float:
    """Linear warmup then cosine decay (ESPnet-style schedule stand-in)."""
    if step < WARMUP:
        return LR_PEAK * (step + 1) / WARMUP
    frac = (step - WARMUP) / max(total - WARMUP, 1)
    return LR_FLOOR + 0.5 * (LR_PEAK - LR_FLOOR) * (1 + np.cos(np.pi * frac))
TEST_UTTS = 64
SEED_TRAIN, SEED_TEST = 7, 1337


# --- Adam (inline, pytree-generic) -------------------------------------------


def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(
        lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(
        lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mh_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vh_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mh_scale)
        / (jnp.sqrt(v_ * vh_scale) + eps),
        params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


# --- ASR ----------------------------------------------------------------------


def train_asr(steps: int = ASR_TRAIN_STEPS, log_every: int = 25,
              seed: int = SEED_TRAIN):
    cfg = ASR_TINY
    params = init_params(cfg, seed=0)
    print(f"[asr] {num_params(params):,} params, {steps} steps")
    masks = full_masks(cfg)
    opt = adam_init(params)
    rng = np.random.default_rng(seed)
    templates = D._char_templates(np.random.default_rng(SEED_TEST))

    @jax.jit
    def loss_fn(p, feats, pad, flen, labels, llen):
        lp = asr_forward(p, feats, pad, masks, cfg, use_pallas=False)
        return jnp.mean(ctc_loss(lp, flen, labels, llen, blank=D.CTC_BLANK))

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    log = []
    t0 = time.time()
    for step in range(steps):
        feats, flen, labels, llen = D.make_asr_batch(rng, templates, BATCH)
        pad = (np.arange(D.ASR_MAX_FRAMES)[None] < flen[:, None]).astype(
            np.float32)
        loss, grads = grad_fn(params, feats, pad, flen, labels, llen)
        params, opt = adam_update(params, grads, opt, lr=lr_at(step, steps))
        if step % log_every == 0 or step == steps - 1:
            log.append({"step": step, "loss": float(loss),
                        "wall_s": round(time.time() - t0, 2)})
            print(f"[asr] step {step:4d} loss {float(loss):8.4f}")
    return cfg, params, log


def eval_asr_wer(cfg: ModelConfig, params, feats, flen, labels, llen) -> float:
    """Character-task WER over space-delimited 'words' (paper's metric)."""
    masks = full_masks(cfg)
    pad = (np.arange(feats.shape[1])[None] < flen[:, None]).astype(np.float32)
    lp = asr_forward(params, feats, pad, masks, cfg, use_pallas=False)
    hyps = greedy_decode(np.asarray(lp), flen, blank=D.CTC_BLANK)
    errs = tot = 0
    for b, hyp in enumerate(hyps):
        ref = list(labels[b, : int(llen[b])])
        errs += _edit_distance(hyp, [int(x) for x in ref])
        tot += len(ref)
    return errs / max(tot, 1)


def _edit_distance(a, b) -> int:
    la, lb = len(a), len(b)
    prev = list(range(lb + 1))
    for i in range(1, la + 1):
        cur = [i] + [0] * lb
        for j in range(1, lb + 1):
            cur[j] = min(prev[j] + 1, cur[j - 1] + 1,
                         prev[j - 1] + (a[i - 1] != b[j - 1]))
        prev = cur
    return prev[lb]


# --- MT -----------------------------------------------------------------------


def train_mt(steps: int = MT_TRAIN_STEPS, log_every: int = 25,
             seed: int = SEED_TRAIN + 1):
    cfg = MT_TINY
    params = init_params(cfg, seed=1)
    print(f"[mt] {num_params(params):,} params, {steps} steps")
    masks = full_masks(cfg)
    opt = adam_init(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def loss_fn(p, src, tgt):
        logits = mt_forward(p, src, masks, cfg, use_pallas=False)
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)
        return jnp.mean(nll)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    log = []
    for step in range(steps):
        src = rng.integers(0, D.MT_VOCAB,
                           size=(BATCH, D.MT_SEQ_LEN)).astype(np.int32)
        tgt = np.stack([D.mt_translate(s) for s in src]).astype(np.int32)
        loss, grads = grad_fn(params, src, tgt)
        params, opt = adam_update(params, grads, opt, lr=lr_at(step, steps))
        if step % log_every == 0 or step == steps - 1:
            log.append({"step": step, "loss": float(loss)})
            print(f"[mt] step {step:4d} loss {float(loss):8.4f}")
    return cfg, params, log


# --- entry --------------------------------------------------------------------


def main():
    os.makedirs(ART, exist_ok=True)

    cfg, params, log = train_asr()
    templates, (feats, flen, labels, llen) = D.make_asr_dataset(
        SEED_TEST, TEST_UTTS)
    wer = eval_asr_wer(cfg, params, feats, flen, labels, llen)
    print(f"[asr] clean test WER = {wer:.4f}")
    log.append({"step": -1, "test_wer": wer})
    out = {k: np.asarray(v) for k, v in params.items()}
    # Fixed PE table rides along as an artifact argument (see model.py).
    from .model import sinusoidal_pe
    out["pos_enc"] = sinusoidal_pe(D.ASR_MAX_FRAMES, cfg.d_model)
    save_tensors(os.path.join(ART, "params_asr.bin"), out)
    save_tensors(os.path.join(ART, "testset_asr.bin"), {
        "feats": feats, "feat_len": flen, "labels": labels,
        "label_len": llen,
    })
    with open(os.path.join(ART, "train_log_asr.json"), "w") as f:
        json.dump(log, f, indent=1)

    cfg_mt, params_mt, log_mt = train_mt()
    src, tgt = D.make_mt_dataset(SEED_TEST + 1, TEST_UTTS)
    out_mt = {k: np.asarray(v) for k, v in params_mt.items()}
    from .model import sinusoidal_pe as _pe
    out_mt["pos_enc"] = _pe(D.MT_SEQ_LEN, cfg_mt.d_model)
    save_tensors(os.path.join(ART, "params_mt.bin"), out_mt)
    save_tensors(os.path.join(ART, "testset_mt.bin"), {"src": src, "tgt": tgt})
    with open(os.path.join(ART, "train_log_mt.json"), "w") as f:
        json.dump(log_mt, f, indent=1)
    print("[train] artifacts written")


if __name__ == "__main__":
    sys.exit(main())
