"""AOT bridge: lower the Layer-2 model (with Layer-1 Pallas kernels) to HLO
*text* artifacts that the rust runtime loads via PJRT.

HLO text — not ``lowered.compile()`` or serialized ``HloModuleProto`` — is
the interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
that the ``xla`` crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (each ``<name>.hlo.txt`` + ``<name>_manifest.json``):

    asr_encoder_sasp  — encoder with Pallas SASP FF kernels; tile masks are
                        runtime inputs. Proves the 3-layer composition.
    asr_encoder_ref   — same math via the jnp oracle (dense matmuls): the
                        fast path for the big QoS sweeps (identical
                        numerics — pruned weights are zeros either way).
    mt_encoder_ref    — MT model, oracle path.
    sasp_gemm_t8      — the Layer-1 kernel in isolation (microbench +
                        rust-vs-python golden tests).
    quant_gemm_t8     — INT8-weight variant in isolation.

The manifest records the exact positional argument contract (names,
shapes, dtypes) the rust coordinator must follow, plus model metadata.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from .kernels.ref import quantize_ref
from .kernels.sasp_gemm import sasp_gemm, sasp_quant_gemm
from .model import (ASR_TINY, MT_TINY, ModelConfig, asr_forward,
                    ff_mask_shapes, init_params, mt_forward, param_names)
from .tensorio import load_tensors

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

ASR_BATCH, ASR_T = 16, D.ASR_MAX_FRAMES
MT_BATCH, MT_L = 16, D.MT_SEQ_LEN
GEMM_M, GEMM_K, GEMM_N, GEMM_TILE = 64, 64, 64, 8


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _manifest_entry(name, shape, dtype):
    return {"name": name, "shape": [int(d) for d in shape],
            "dtype": str(np.dtype(dtype))}


def _mask_arg_names(cfg: ModelConfig):
    out = []
    for i in range(cfg.n_blocks):
        out += [f"mask.block{i}.ff1", f"mask.block{i}.ff2"]
    return out


def export_encoder(task: str, cfg: ModelConfig, use_pallas: bool,
                   out_name: str, outdir: str):
    """Lower an encoder variant; weights/masks are positional args."""
    names = param_names(cfg)
    mask_names = _mask_arg_names(cfg)
    mshapes = [s for pair in ff_mask_shapes(cfg) for s in pair]

    params0 = init_params(cfg)  # shapes only; values come at runtime
    pshapes = [params0[n].shape for n in names]

    # The position table is an *argument*: XLA's HLO-text printer elides
    # large constants and the text parser zero-fills them (see model.py).
    if task == "asr":
        data_args = [
            _manifest_entry("feats", (ASR_BATCH, ASR_T, cfg.input_dim),
                            np.float32),
            _manifest_entry("pad_mask", (ASR_BATCH, ASR_T), np.float32),
            _manifest_entry("pos_enc", (ASR_T, cfg.d_model), np.float32),
        ]

        def fn(feats, pad, pos_enc, *rest):
            masks = list(rest[: len(mask_names)])
            plist = rest[len(mask_names):]
            params = dict(zip(names, plist))
            return (asr_forward(params, feats, pad, masks, cfg,
                                pos_enc=pos_enc,
                                use_pallas=use_pallas, interpret=True),)

        specs = [_spec(e["shape"]) for e in data_args]
        out_shape = (ASR_BATCH, ASR_T, cfg.vocab)
    elif task == "mt":
        data_args = [
            _manifest_entry("src", (MT_BATCH, MT_L), np.int32),
            _manifest_entry("pos_enc", (MT_L, cfg.d_model), np.float32),
        ]

        def fn(src, pos_enc, *rest):
            masks = list(rest[: len(mask_names)])
            plist = rest[len(mask_names):]
            params = dict(zip(names, plist))
            return (mt_forward(params, src, masks, cfg, pos_enc=pos_enc,
                               use_pallas=use_pallas, interpret=True),)

        specs = [_spec(data_args[0]["shape"], jnp.int32),
                 _spec(data_args[1]["shape"])]
        out_shape = (MT_BATCH, MT_L, cfg.vocab)
    else:
        raise ValueError(task)

    specs += [_spec(s, jnp.int32) for s in mshapes]
    specs += [_spec(s) for s in pshapes]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    # Guard against silent zero-fill of elided constants on the rust side.
    assert "constant({...}" not in text.replace(" ", ""), (
        f"{out_name}: HLO text contains an elided large constant — "
        "pass it as an argument instead")

    manifest = {
        "name": out_name,
        "task": task,
        "args": (data_args
                 + [_manifest_entry(n, s, np.int32)
                    for n, s in zip(mask_names, mshapes)]
                 + [_manifest_entry(n, s, np.float32)
                    for n, s in zip(names, pshapes)]),
        "output": {"shape": list(out_shape), "dtype": "float32"},
        "model": {
            "d_model": cfg.d_model, "d_ff": cfg.d_ff,
            "n_blocks": cfg.n_blocks, "n_heads": cfg.n_heads,
            "vocab": cfg.vocab, "tile": cfg.tile,
            "input_dim": cfg.input_dim, "token_input": cfg.token_input,
            "ctc_blank": D.CTC_BLANK if task == "asr" else -1,
            "batch": ASR_BATCH if task == "asr" else MT_BATCH,
            "seq_len": ASR_T if task == "asr" else MT_L,
        },
        "use_pallas": use_pallas,
    }
    _write(outdir, out_name, text, manifest)


def export_gemm_kernels(outdir: str):
    """The Layer-1 kernels in isolation, tile=8."""
    m, k, n, t = GEMM_M, GEMM_K, GEMM_N, GEMM_TILE
    x = _spec((m, k))
    w = _spec((k, n))
    mask = _spec((k // t, n // t), jnp.int32)

    def fn(x, w, mask):
        return (sasp_gemm(x, w, mask, tile=t, interpret=True),)

    text = to_hlo_text(jax.jit(fn).lower(x, w, mask))
    _write(outdir, "sasp_gemm_t8", text, {
        "name": "sasp_gemm_t8",
        "args": [_manifest_entry("x", (m, k), np.float32),
                 _manifest_entry("w", (k, n), np.float32),
                 _manifest_entry("mask", (k // t, n // t), np.int32)],
        "output": {"shape": [m, n], "dtype": "float32"},
        "tile": t,
    })

    wq = _spec((k, n), jnp.int8)
    scale = _spec((1,))

    def fnq(x, wq, scale, mask):
        return (sasp_quant_gemm(x, wq, scale, mask, tile=t, interpret=True),)

    text = to_hlo_text(jax.jit(fnq).lower(x, wq, scale, mask))
    _write(outdir, "quant_gemm_t8", text, {
        "name": "quant_gemm_t8",
        "args": [_manifest_entry("x", (m, k), np.float32),
                 _manifest_entry("w_q", (k, n), np.int8),
                 _manifest_entry("scale", (1,), np.float32),
                 _manifest_entry("mask", (k // t, n // t), np.int32)],
        "output": {"shape": [m, n], "dtype": "float32"},
        "tile": t,
    })


def export_goldens(outdir: str):
    """Golden input/output pairs for the rust integration tests."""
    from .tensorio import save_tensors
    from .kernels.ref import sasp_gemm_ref, sasp_quant_gemm_ref

    rng = np.random.default_rng(99)
    m, k, n, t = GEMM_M, GEMM_K, GEMM_N, GEMM_TILE
    x = rng.normal(size=(m, k)).astype(np.float32)
    w = rng.normal(size=(k, n)).astype(np.float32)
    mask = (rng.random((k // t, n // t)) > 0.3).astype(np.int32)
    y = np.asarray(sasp_gemm_ref(x, w, mask, tile=t))
    wq, scale = quantize_ref(jnp.asarray(w))
    yq = np.asarray(sasp_quant_gemm_ref(x, wq, scale, mask, tile=t))
    save_tensors(os.path.join(outdir, "golden_gemm.bin"), {
        "x": x, "w": w, "mask": mask, "y": y,
        "w_q": np.asarray(wq), "scale": np.asarray(scale).reshape(1),
        "y_q": yq,
    })


def _write(outdir, name, hlo_text, manifest):
    hpath = os.path.join(outdir, f"{name}.hlo.txt")
    with open(hpath, "w") as f:
        f.write(hlo_text)
    with open(os.path.join(outdir, f"{name}_manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {hpath} ({len(hlo_text)} chars)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=ART, help="artifacts directory")
    ap.add_argument("--skip-train", action="store_true",
                    help="fail if trained params are missing instead of "
                         "training")
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)

    if not os.path.exists(os.path.join(outdir, "params_asr.bin")):
        if args.skip_train:
            raise SystemExit("trained params missing; run compile.train")
        from . import train
        train.main()

    export_gemm_kernels(outdir)
    export_goldens(outdir)
    export_encoder("asr", ASR_TINY, True, "asr_encoder_sasp", outdir)
    export_encoder("asr", ASR_TINY, False, "asr_encoder_ref", outdir)
    export_encoder("mt", MT_TINY, False, "mt_encoder_ref", outdir)
    print("[aot] done")


if __name__ == "__main__":
    main()
